//! The engine fleet: a replicated executor pool behind one routing handle.
//!
//! The paper's speed-up is per-sample NFE, but a single engine thread is
//! still one execution stream: concurrent bundles serialize on it no
//! matter how many pipeline stages feed it. [`FleetHandle`] spawns `N`
//! full engine replicas — each its own engine thread **and** artifact
//! cache ([`crate::runtime::EngineHandle`]) — and implements [`Executor`]
//! itself, so everything that talks to "the engine" (scheduler, sampler,
//! REFINE workers, benches) transparently talks to the fleet instead.
//! `fleet.replicas = 1` (the default) is today's single-engine behaviour
//! verbatim: one engine thread, one cache, every call routed to it.
//!
//! ## Routing
//!
//! Dispatch is deterministic least-loaded with artifact affinity
//! ([`router`]): healthy replicas only, fewest in-flight calls first,
//! affinity (the replica already holds the artifact's compiled
//! executable) breaking load ties, lowest index breaking the rest. The
//! route+claim step runs under a lock so concurrent dispatchers observe
//! each other's in-flight increments — two idle-fleet dispatches land on
//! two different replicas, never stampede one.
//!
//! ## Failure isolation
//!
//! A replica whose engine thread dies surfaces the typed [`EngineDead`]
//! error, and one whose engine wedges past the watchdog deadline
//! surfaces the typed [`EngineTimeout`] — never a hang. The fleet treats
//! both identically: quarantine (`replica_unhealthy`), re-route the
//! failed call to another healthy replica (`fleet_reroutes`, with the
//! run's init tokens restored from a backup for `run_loop`, whose engine
//! protocol moves token storage), and surface the typed [`FleetDown`]
//! error once no healthy replica remains. Replica deaths are
//! independent: one panicked engine thread never takes the fleet down.
//!
//! ## Resurrection
//!
//! Fleets built with a respawn recipe ([`FleetHandle::spawn_with`] /
//! [`FleetHandle::from_factories`]) run a health loop that brings
//! quarantined replicas back: build a fresh executor (for engine
//! replicas: a new engine thread plus a re-preload of the slot's
//! affinity artifacts), require a passing [`Executor::probe`], then swap
//! it in (`replica_respawns`). Failed attempts (`respawn_failures`) back
//! off exponentially (`robustness.respawn_backoff_ms`, capped) and a
//! circuit breaker retires the slot after `robustness.max_respawns`
//! consecutive failures. Each slot carries a **generation** tag bumped on
//! every respawn; a failure observed by a call that started on an older
//! generation can never quarantine the resurrected replica, and —
//! because the watchdog drops the timed-out call's reply channel — a
//! wedged old engine's late answer is discarded structurally, never
//! delivered stale. Fleets without a recipe ([`FleetHandle::spawn`],
//! [`FleetHandle::from_executors`]) keep permanent-quarantine semantics.
//!
//! ## Determinism
//!
//! Outputs are a pure function of `(config seed, bundle)` — the stateless
//! RNG substream contract established by the engine-resident loop and the
//! pipelined coordinator — so *which* replica refines a bundle (or how
//! many times it was respawned) can never change its tokens.
//! Bitwise-identical outputs across `fleet.replicas × fleet.refine_workers`
//! sweeps are pinned by the coordinator's determinism tests.

pub mod router;

use crate::config::RobustnessConfig;
use crate::fleet::router::{route, Candidate};
use crate::metrics::FleetMetrics;
use crate::obs::{scope, EventKind, Obs, SpanKind};
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::runtime::engine::{
    EngineDead, EngineHandle, EngineStats, EngineTimeout, Executor, LoopReport, LoopScratch,
    LoopSpec,
};
use anyhow::{Context, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Typed error surfaced when every replica in the fleet is unhealthy:
/// callers get a fast, downcastable failure instead of a hang or a
/// generic channel error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetDown;

impl std::fmt::Display for FleetDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "all fleet replicas are down")
    }
}

impl std::error::Error for FleetDown {}

/// Builds a replacement executor for one replica slot (by index).
pub type ReplicaFactory = Box<dyn Fn() -> Result<Arc<dyn Executor>> + Send + Sync>;

/// How the health loop rebuilds a quarantined replica.
enum Respawner {
    /// No recipe: quarantine is permanent (the pre-resurrection
    /// behaviour of [`FleetHandle::spawn`] / `from_executors`).
    None,
    /// Spawn a fresh engine thread over the manifest, re-preload the
    /// slot's affinity artifacts, arm the same watchdog. The manifest is
    /// behind a mutex because [`FleetHandle::swap_artifacts`] republishes
    /// it: respawns after a swap must build against the *new* contract.
    Engine { manifest: Mutex<Manifest>, call_timeout: Option<Duration> },
    /// Call the slot's factory (tests, mock fleets).
    Factories(Vec<ReplicaFactory>),
}

/// The swappable part of a replica slot. `generation` increments on
/// every respawn; failures reported against an older generation are
/// stale and must not quarantine the current executor.
struct ReplicaState {
    exec: Arc<dyn Executor>,
    /// Engine-backed replicas keep the handle for preload/stats/shutdown.
    engine: Option<EngineHandle>,
    generation: u64,
    /// Which artifact contract this replica serves: the fleet's
    /// `swap_epoch` at install time. A mixed fleet (replicas on
    /// different epochs) is a bug [`FleetHandle::swap_artifacts`] is
    /// designed to make impossible.
    manifest_epoch: u64,
}

/// Respawn bookkeeping for one slot (touched only by the health loop).
struct RepairState {
    consecutive_failures: u32,
    next_attempt: Instant,
    /// Circuit breaker tripped: no further respawn attempts.
    retired: bool,
}

/// One replica slot: the swappable executor state, its health flag, the
/// set of artifacts it has been sent (its compile-cache shadow, for
/// affinity — preserved across respawns so the replacement re-warms the
/// same cache), and the respawn bookkeeping.
struct Replica {
    state: Mutex<ReplicaState>,
    healthy: AtomicBool,
    artifacts: Mutex<HashSet<String>>,
    repair: Mutex<RepairState>,
}

impl Replica {
    fn new(exec: Arc<dyn Executor>, engine: Option<EngineHandle>) -> Replica {
        Replica {
            state: Mutex::new(ReplicaState { exec, engine, generation: 0, manifest_epoch: 0 }),
            healthy: AtomicBool::new(true),
            artifacts: Mutex::new(HashSet::new()),
            repair: Mutex::new(RepairState {
                consecutive_failures: 0,
                next_attempt: Instant::now(),
                retired: false,
            }),
        }
    }
}

struct FleetInner {
    replicas: Vec<Replica>,
    metrics: FleetMetrics,
    /// Serializes route+claim so concurrent dispatchers see each other's
    /// in-flight increments (without it, two simultaneous dispatches on an
    /// idle fleet would both pick replica 0).
    router_lock: Mutex<()>,
    respawner: Respawner,
    robustness: RobustnessConfig,
    /// Signals the health loop to exit (set by [`FleetHandle::shutdown`]).
    stop: AtomicBool,
    /// Bumped once per published [`FleetHandle::swap_artifacts`]. Repair
    /// builds snapshot it and discard themselves if it moved — a respawn
    /// racing a swap can never readmit an old-contract engine.
    swap_epoch: AtomicU64,
    /// Serializes concurrent `swap_artifacts` calls.
    swap_lock: Mutex<()>,
    /// Observability hub ([`FleetHandle::attach_obs`]): typed lifecycle
    /// events mirror the [`FleetMetrics`] counters 1:1 and dispatches
    /// record engine-call spans. `None` (unattached) records nothing.
    obs: Mutex<Option<Arc<Obs>>>,
}

impl FleetInner {
    /// The attached, enabled hub — `None` short-circuits every recording.
    fn obs(&self) -> Option<Arc<Obs>> {
        self.obs.lock().unwrap().as_ref().filter(|o| o.enabled()).cloned()
    }

    /// Journal one lifecycle event. `detail` is lazy so the hot path pays
    /// no allocation when no hub is attached (or it is disabled).
    fn event(&self, kind: EventKind, replica: Option<usize>, detail: impl FnOnce() -> String) {
        if let Some(obs) = self.obs() {
            obs.event(kind, replica, detail());
        }
    }
}

/// Health-loop poll cadence (how often quarantined slots are checked for
/// a due respawn attempt; the actual retry schedule is the backoff).
const HEALTH_POLL: Duration = Duration::from_millis(5);

/// Cloneable, thread-safe front-end to the replica pool; implements
/// [`Executor`] so it drops in anywhere an engine handle does.
#[derive(Clone)]
pub struct FleetHandle {
    inner: Arc<FleetInner>,
}

impl FleetHandle {
    /// Spawn `replicas` engine replicas over a manifest (each its own
    /// engine thread + artifact cache). `replicas` is floored at 1. No
    /// watchdog, no health loop: quarantine is permanent — the legacy
    /// behaviour. Production serving uses [`FleetHandle::spawn_with`].
    pub fn spawn(manifest: Manifest, replicas: usize) -> Result<FleetHandle> {
        let n = replicas.max(1);
        let mut slots = Vec::with_capacity(n);
        for i in 0..n {
            let engine = EngineHandle::spawn(manifest.clone())
                .with_context(|| format!("spawning fleet replica {i}"))?;
            slots.push(Replica::new(Arc::new(engine.clone()), Some(engine)));
        }
        Ok(FleetHandle::from_slots(slots, Respawner::None, RobustnessConfig::default()))
    }

    /// [`FleetHandle::spawn`] plus the fault-tolerance envelope: every
    /// replica's calls run under the `robustness.call_timeout_ms`
    /// watchdog, and a health loop resurrects quarantined replicas
    /// (fresh engine thread + affinity re-preload + passing probe) with
    /// capped exponential backoff and a `max_respawns` circuit breaker.
    pub fn spawn_with(
        manifest: Manifest,
        replicas: usize,
        robustness: &RobustnessConfig,
    ) -> Result<FleetHandle> {
        let n = replicas.max(1);
        let call_timeout = robustness.call_timeout();
        let mut slots = Vec::with_capacity(n);
        for i in 0..n {
            let engine = EngineHandle::spawn(manifest.clone())
                .with_context(|| format!("spawning fleet replica {i}"))?
                .with_call_timeout(call_timeout);
            slots.push(Replica::new(Arc::new(engine.clone()), Some(engine)));
        }
        let respawner = Respawner::Engine { manifest: Mutex::new(manifest), call_timeout };
        let fleet = FleetHandle::from_slots(slots, respawner, robustness.clone());
        fleet.spawn_health_loop();
        Ok(fleet)
    }

    /// Build a fleet over arbitrary executors (tests, benches: mock
    /// replicas with controlled behaviour). No health loop: quarantine
    /// is permanent. Panics on an empty pool.
    pub fn from_executors(execs: Vec<Arc<dyn Executor>>) -> FleetHandle {
        let slots = execs.into_iter().map(|exec| Replica::new(exec, None)).collect();
        FleetHandle::from_slots(slots, Respawner::None, RobustnessConfig::default())
    }

    /// Build a fleet where each slot knows how to rebuild itself: the
    /// health loop respawns a quarantined slot by calling its factory
    /// (probe-gated, backed off, circuit-broken per `robustness`).
    /// Panics on an empty pool; errors if an initial build fails.
    pub fn from_factories(
        factories: Vec<ReplicaFactory>,
        robustness: &RobustnessConfig,
    ) -> Result<FleetHandle> {
        let mut slots = Vec::with_capacity(factories.len());
        for (i, f) in factories.iter().enumerate() {
            let exec = f().with_context(|| format!("building fleet replica {i}"))?;
            slots.push(Replica::new(exec, None));
        }
        let fleet =
            FleetHandle::from_slots(slots, Respawner::Factories(factories), robustness.clone());
        fleet.spawn_health_loop();
        Ok(fleet)
    }

    fn from_slots(
        slots: Vec<Replica>,
        respawner: Respawner,
        robustness: RobustnessConfig,
    ) -> FleetHandle {
        assert!(!slots.is_empty(), "fleet needs at least one replica");
        let metrics = FleetMetrics::new(slots.len());
        FleetHandle {
            inner: Arc::new(FleetInner {
                replicas: slots,
                metrics,
                router_lock: Mutex::new(()),
                respawner,
                robustness,
                stop: AtomicBool::new(false),
                swap_epoch: AtomicU64::new(0),
                swap_lock: Mutex::new(()),
                obs: Mutex::new(None),
            }),
        }
    }

    /// Start the resurrection thread. It holds only a `Weak` to the pool
    /// — dropping the last handle (or `shutdown`) ends it.
    fn spawn_health_loop(&self) {
        let weak = Arc::downgrade(&self.inner);
        std::thread::Builder::new()
            .name("wsfm-fleet-health".into())
            .spawn(move || loop {
                std::thread::sleep(HEALTH_POLL);
                let Some(inner) = weak.upgrade() else { return };
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
                for idx in 0..inner.replicas.len() {
                    try_repair(&inner, idx);
                }
            })
            .expect("spawning fleet health thread");
    }

    /// Total replicas (healthy or not).
    pub fn replicas(&self) -> usize {
        self.inner.replicas.len()
    }

    /// Replicas still accepting work.
    pub fn healthy_replicas(&self) -> usize {
        self.inner.replicas.iter().filter(|r| r.healthy.load(Ordering::SeqCst)).count()
    }

    /// The fleet's routing/health metrics (per-replica inflight gauges,
    /// unhealthy + reroute + respawn counters).
    pub fn metrics(&self) -> &FleetMetrics {
        &self.inner.metrics
    }

    /// Attach an observability hub ([`crate::obs::Obs`]): every fleet
    /// lifecycle transition (quarantine, reroute, respawn, watchdog
    /// timeout, artifact swap/rollback) is journaled as a typed event
    /// exactly 1:1 with its counter increment, and each dispatch records
    /// an engine-call span tagged with the replica index and the ambient
    /// bundle id ([`crate::obs::scope`]). The serving CLI attaches the
    /// service's hub at startup; an unattached fleet records nothing.
    pub fn attach_obs(&self, obs: Arc<Obs>) {
        *self.inner.obs.lock().unwrap() = Some(obs);
    }

    /// Route + claim a replica for `artifact` under the router lock:
    /// increments its inflight gauge and records the artifact in its
    /// affinity set before releasing the lock. Returns the slot index,
    /// the claimed executor, and its generation (for stale-failure
    /// detection at quarantine time).
    fn claim(&self, artifact: &str) -> Result<(usize, u64, Arc<dyn Executor>)> {
        let m = &self.inner.metrics;
        let _g = self.inner.router_lock.lock().unwrap();
        let candidates: Vec<Candidate> = self
            .inner
            .replicas
            .iter()
            .enumerate()
            .map(|(index, r)| Candidate {
                index,
                healthy: r.healthy.load(Ordering::SeqCst),
                inflight: m.replica_inflight[index].get(),
                has_artifact: r.artifacts.lock().unwrap().contains(artifact),
            })
            .collect();
        let idx = route(&candidates).ok_or_else(|| anyhow::Error::new(FleetDown))?;
        m.replica_inflight[idx].inc();
        m.replica_dispatched[idx].inc();
        // candidates[idx].index == idx (built in order); skip the String
        // allocation + re-lock once the artifact is known resident.
        if !candidates[idx].has_artifact {
            self.inner.replicas[idx].artifacts.lock().unwrap().insert(artifact.to_string());
        }
        let state = self.inner.replicas[idx].state.lock().unwrap();
        Ok((idx, state.generation, state.exec.clone()))
    }

    /// Quarantine slot `idx` — unless the failure is stale: a call that
    /// started on generation `generation` but failed after the health
    /// loop swapped in generation `generation + 1` must not take down
    /// the fresh replica. The generation check and the health flip
    /// happen under the slot's state lock, the same lock the respawn
    /// swap-in holds, so the two can never interleave inconsistently.
    fn quarantine(&self, idx: usize, generation: u64) {
        let replica = &self.inner.replicas[idx];
        let state = replica.state.lock().unwrap();
        if state.generation != generation {
            crate::info!("fleet: ignoring stale failure from replica {idx} gen {generation}");
            return;
        }
        // swap() keeps the unhealthy counter exact when two in-flight
        // calls observe the same death.
        if replica.healthy.swap(false, Ordering::SeqCst) {
            self.inner.metrics.replica_unhealthy.inc();
            self.inner.event(EventKind::Quarantine, Some(idx), || {
                format!("replica {idx} dead or wedged (gen {generation})")
            });
            crate::error!("fleet: replica {idx} unusable (dead or wedged); re-routing its work");
        }
    }

    /// Run `call` on the routed replica. On the typed [`EngineDead`] or
    /// [`EngineTimeout`] errors the replica is quarantined and the call
    /// re-routed; every other error (bad artifact, shape mismatch)
    /// returns unchanged — it would fail identically anywhere. Because
    /// resurrection can re-admit a replica mid-dispatch, the old "each
    /// death removes a candidate" bound no longer holds; attempts are
    /// capped at `replicas + 1`, after which the last typed error
    /// surfaces (an empty pool still fails fast with [`FleetDown`] at
    /// claim time).
    fn dispatch<T>(
        &self,
        artifact: &str,
        mut call: impl FnMut(&dyn Executor) -> Result<T>,
    ) -> Result<T> {
        let m = &self.inner.metrics;
        let max_attempts = self.replicas() + 1;
        let mut attempt = 0usize;
        loop {
            let (idx, generation, exec) = self.claim(artifact)?;
            if attempt > 0 {
                m.fleet_reroutes.inc();
                scope::note_reroute();
                self.inner.event(EventKind::Reroute, Some(idx), || {
                    format!("attempt {} for {artifact} re-routed to replica {idx}", attempt + 1)
                });
            }
            attempt += 1;
            scope::note_replica(idx as u32);
            let t_call = Instant::now();
            let result = call(&*exec);
            m.replica_inflight[idx].dec();
            if let Some(obs) = self.inner.obs() {
                obs.span(
                    0,
                    scope::bundle_id(),
                    SpanKind::EngineCall,
                    idx as u32,
                    t_call,
                    t_call.elapsed(),
                );
            }
            match result {
                Err(e)
                    if e.downcast_ref::<EngineDead>().is_some()
                        || e.downcast_ref::<EngineTimeout>().is_some() =>
                {
                    if e.downcast_ref::<EngineTimeout>().is_some() {
                        m.engine_timeouts.inc();
                        self.inner.event(EventKind::EngineTimeout, Some(idx), || {
                            format!("watchdog timeout on {artifact}")
                        });
                    }
                    self.quarantine(idx, generation);
                    if attempt >= max_attempts {
                        return Err(e);
                    }
                }
                other => return other,
            }
        }
    }

    /// Eagerly compile `names` on **every** engine-backed replica.
    /// Duplicate compilation is deliberate here — preload is the operator
    /// buying compile time up front so no replica pays it on the request
    /// path — and the affinity sets are updated to match. A replica that
    /// answers with [`EngineDead`] is quarantined, not fatal (the same
    /// failure-isolation contract as dispatch: one dead engine never
    /// takes the fleet down); ordinary compile errors still propagate,
    /// and an entirely dead pool surfaces [`FleetDown`].
    pub fn preload(&self, names: &[String]) -> Result<()> {
        for (i, r) in self.inner.replicas.iter().enumerate() {
            let (engine, generation) = {
                let state = r.state.lock().unwrap();
                (state.engine.clone(), state.generation)
            };
            let Some(engine) = engine else { continue };
            if !r.healthy.load(Ordering::SeqCst) {
                continue;
            }
            match engine.preload(names) {
                Ok(()) => r.artifacts.lock().unwrap().extend(names.iter().cloned()),
                Err(e) if e.downcast_ref::<EngineDead>().is_some() => {
                    crate::error!("fleet: replica {i} engine died during preload; quarantined");
                    self.quarantine(i, generation);
                }
                Err(e) => return Err(e.context(format!("preloading fleet replica {i}"))),
            }
        }
        if self.healthy_replicas() == 0 {
            return Err(anyhow::Error::new(FleetDown));
        }
        Ok(())
    }

    /// Per-replica engine statistics (`None` for non-engine replicas and
    /// for dead engines).
    pub fn engine_stats(&self) -> Vec<Option<EngineStats>> {
        self.inner
            .replicas
            .iter()
            .map(|r| {
                let engine = r.state.lock().unwrap().engine.clone();
                engine.and_then(|e| e.stats().ok())
            })
            .collect()
    }

    /// Multi-line human summary for the serve/selfcheck CLI: the fleet
    /// counters plus one line per replica.
    pub fn summary(&self) -> String {
        let mut s = self.inner.metrics.summary();
        for (i, r) in self.inner.replicas.iter().enumerate() {
            let health = if r.healthy.load(Ordering::SeqCst) { "" } else { " (unhealthy)" };
            let engine = r.state.lock().unwrap().engine.clone();
            match engine {
                Some(engine) => match engine.stats() {
                    Ok(es) => s.push_str(&format!("\n  replica {i}{health}: {}", es.summary())),
                    Err(_) => s.push_str(&format!("\n  replica {i}{health}: engine dead")),
                },
                None => s.push_str(&format!("\n  replica {i}{health}: (non-engine executor)")),
            }
        }
        s
    }

    /// Shut down every engine-backed replica and stop the health loop.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        for r in &self.inner.replicas {
            let engine = r.state.lock().unwrap().engine.clone();
            if let Some(engine) = engine {
                engine.shutdown();
            }
        }
    }

    /// The manifest epoch each replica currently serves (the fleet-wide
    /// swap counter at its install time). A correct fleet is uniform:
    /// every entry equal — [`FleetHandle::swap_artifacts`] either moves
    /// all replicas to the new epoch or none of them.
    pub fn manifest_epochs(&self) -> Vec<u64> {
        self.inner.replicas.iter().map(|r| r.state.lock().unwrap().manifest_epoch).collect()
    }

    /// Hot-swap the artifact contract: point every replica at `manifest`
    /// without dropping the fleet, **all-or-nothing**.
    ///
    /// Phase 1 (no locks held): verify the manifest's content hashes,
    /// then build one replacement engine per slot — fresh engine thread
    /// over the new manifest, re-preload of the slot's affinity artifacts
    /// (those still present in the new contract), and a passing
    /// [`Executor::probe`]. Any failure shuts down everything built so
    /// far and returns with the old fleet untouched
    /// (`artifact_swap_rollbacks`).
    ///
    /// Phase 2: publish. The fleet's swap epoch is bumped first (so a
    /// concurrent health-loop respawn built against the old manifest
    /// discards itself instead of readmitting a stale contract), the
    /// respawner's manifest is replaced, and each slot's probed
    /// replacement is installed under its state lock — generation bumped,
    /// epoch stamped, health and repair state reset. Installation is pure
    /// pointer swapping: once phase 1 succeeds the swap cannot strand the
    /// fleet mixed, even if replicas are killed mid-swap (a kill only
    /// shuts down an engine about to be replaced).
    ///
    /// Only engine-backed fleets can swap; a slot without an engine
    /// (mock/factory executors) is an error before anything is built.
    pub fn swap_artifacts(&self, manifest: Manifest) -> Result<()> {
        let _swap = self.inner.swap_lock.lock().unwrap();
        let report = manifest.verify_hashes().context("verifying new manifest before swap")?;
        if !report.ok() {
            self.inner.metrics.artifact_swap_rollbacks.inc();
            let names: Vec<&str> = report.mismatches.iter().map(|(n, _, _)| n.as_str()).collect();
            self.inner.event(EventKind::ArtifactRollback, None, || {
                format!("content hash mismatch for {names:?}")
            });
            anyhow::bail!("artifact swap rejected: content hash mismatch for {names:?} ({report})");
        }
        let call_timeout = match &self.inner.respawner {
            Respawner::Engine { call_timeout, .. } => *call_timeout,
            _ => None,
        };
        // Phase 1: build + preload + probe a full replacement set.
        let mut replacements: Vec<EngineHandle> = Vec::with_capacity(self.replicas());
        for (i, r) in self.inner.replicas.iter().enumerate() {
            let built: Result<EngineHandle> = (|| {
                if r.state.lock().unwrap().engine.is_none() {
                    anyhow::bail!("replica {i} is not engine-backed");
                }
                let engine = EngineHandle::spawn(manifest.clone())
                    .with_context(|| format!("spawning replacement for replica {i}"))?
                    .with_call_timeout(call_timeout);
                // Re-warm the slot's compile cache — but only for
                // artifacts the new contract still ships.
                let names: Vec<String> = r
                    .artifacts
                    .lock()
                    .unwrap()
                    .iter()
                    .filter(|n| manifest.artifacts.iter().any(|a| &a.name == *n))
                    .cloned()
                    .collect();
                if !names.is_empty() {
                    engine
                        .preload(&names)
                        .with_context(|| format!("preloading replacement for replica {i}"))?;
                }
                engine
                    .probe()
                    .with_context(|| format!("probing replacement for replica {i}"))?;
                Ok(engine)
            })();
            match built {
                Ok(engine) => replacements.push(engine),
                Err(e) => {
                    for b in &replacements {
                        b.shutdown();
                    }
                    self.inner.metrics.artifact_swap_rollbacks.inc();
                    self.inner.event(EventKind::ArtifactRollback, None, || format!("{e:#}"));
                    return Err(e.context("artifact swap rolled back; old fleet untouched"));
                }
            }
        }
        // Phase 2: publish. Epoch first — from here on, in-flight repair
        // builds against the old manifest are inert.
        let epoch = self.inner.swap_epoch.fetch_add(1, Ordering::SeqCst) + 1;
        if let Respawner::Engine { manifest: m, .. } = &self.inner.respawner {
            *m.lock().unwrap() = manifest.clone();
        }
        for (r, engine) in self.inner.replicas.iter().zip(replacements) {
            {
                let mut state = r.state.lock().unwrap();
                if let Some(old) = &state.engine {
                    old.shutdown();
                }
                state.exec = Arc::new(engine.clone());
                state.engine = Some(engine);
                state.generation += 1;
                state.manifest_epoch = epoch;
                r.healthy.store(true, Ordering::SeqCst);
            }
            // Fresh engine, fresh start: a slot retired by the circuit
            // breaker under the old contract is back in play.
            let mut repair = r.repair.lock().unwrap();
            repair.consecutive_failures = 0;
            repair.retired = false;
        }
        self.inner.metrics.artifact_swaps.inc();
        self.inner.event(EventKind::ArtifactSwap, None, || format!("published epoch {epoch}"));
        crate::info!("fleet: artifact swap published (epoch {epoch})");
        Ok(())
    }

    /// Test hook: kill `idx` right now — shut down its engine (if any)
    /// and quarantine it, exactly as a dispatch observing the death
    /// would. The health loop (if running) takes it from there.
    #[cfg(test)]
    pub(crate) fn kill_replica(&self, idx: usize) {
        let (engine, generation) = {
            let state = self.inner.replicas[idx].state.lock().unwrap();
            (state.engine.clone(), state.generation)
        };
        if let Some(engine) = engine {
            engine.shutdown();
        }
        self.quarantine(idx, generation);
    }
}

/// One health-loop pass over slot `idx`: if it is quarantined, not
/// retired, and its backoff has elapsed, build a replacement, require a
/// passing probe, and swap it in under the state lock (bumping the
/// generation so stale failures from the old incarnation are inert).
fn try_repair(inner: &Arc<FleetInner>, idx: usize) {
    let replica = &inner.replicas[idx];
    if replica.healthy.load(Ordering::SeqCst) {
        return;
    }
    {
        let repair = replica.repair.lock().unwrap();
        if repair.retired || Instant::now() < repair.next_attempt {
            return;
        }
    }
    // Build outside all locks: engine spawn + preload can take a while.
    // Snapshot the swap epoch first: if a swap_artifacts publishes while
    // we build, this replacement embodies the old contract and must be
    // discarded, not installed.
    let epoch = inner.swap_epoch.load(Ordering::SeqCst);
    let built: Result<(Arc<dyn Executor>, Option<EngineHandle>)> = match &inner.respawner {
        Respawner::None => return, // no recipe: permanent quarantine
        Respawner::Engine { manifest, call_timeout } => (|| {
            let manifest = manifest.lock().unwrap().clone();
            let engine = EngineHandle::spawn(manifest)
                .with_context(|| format!("respawning fleet replica {idx}"))?
                .with_call_timeout(*call_timeout);
            let names: Vec<String> =
                replica.artifacts.lock().unwrap().iter().cloned().collect();
            if !names.is_empty() {
                engine
                    .preload(&names)
                    .with_context(|| format!("re-preloading fleet replica {idx}"))?;
            }
            Ok((Arc::new(engine.clone()) as Arc<dyn Executor>, Some(engine)))
        })(),
        Respawner::Factories(factories) => factories[idx]().map(|exec| (exec, None)),
    };
    // Readmission is probe-gated: a replacement that cannot answer a
    // health check never enters the routing pool.
    let probed = built.and_then(|(exec, engine)| {
        exec.probe().context("probing respawned replica")?;
        Ok((exec, engine))
    });
    match probed {
        Ok((exec, engine)) => {
            {
                let mut state = replica.state.lock().unwrap();
                // Checked under the slot lock — the same lock
                // swap_artifacts installs under — so the decision cannot
                // interleave with a publication: shutting down, or a swap
                // published a new contract while we built against the old
                // one, means discard (the next poll rebuilds fresh).
                if inner.stop.load(Ordering::SeqCst)
                    || inner.swap_epoch.load(Ordering::SeqCst) != epoch
                {
                    drop(state);
                    if let Some(e) = &engine {
                        e.shutdown();
                    }
                    return;
                }
                if let Some(old) = &state.engine {
                    old.shutdown();
                }
                state.exec = exec;
                state.engine = engine;
                state.generation += 1;
                state.manifest_epoch = epoch;
                replica.healthy.store(true, Ordering::SeqCst);
            }
            replica.repair.lock().unwrap().consecutive_failures = 0;
            inner.metrics.replica_respawns.inc();
            inner.event(EventKind::Respawn, Some(idx), || {
                format!("replica {idx} resurrected (probe passed)")
            });
            crate::info!("fleet: replica {idx} resurrected (probe passed)");
        }
        Err(e) => {
            inner.metrics.respawn_failures.inc();
            inner.event(EventKind::RespawnFailed, Some(idx), || format!("{e:#}"));
            let mut repair = replica.repair.lock().unwrap();
            repair.consecutive_failures += 1;
            if repair.consecutive_failures >= inner.robustness.max_respawns {
                repair.retired = true;
                crate::error!(
                    "fleet: replica {idx} retired after {} failed respawns: {e:#}",
                    repair.consecutive_failures
                );
            } else {
                let exp = inner
                    .robustness
                    .respawn_backoff_ms
                    .saturating_mul(1u64 << (repair.consecutive_failures - 1).min(16));
                let backoff = exp.min(inner.robustness.respawn_backoff_cap_ms);
                repair.next_attempt = Instant::now() + Duration::from_millis(backoff);
                crate::error!("fleet: replica {idx} respawn failed (retry in {backoff} ms): {e:#}");
            }
        }
    }
}

impl Executor for FleetHandle {
    fn step_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.dispatch(artifact, |exec| exec.step_into(artifact, tokens, t, h, warp, out))
    }

    fn step_rows_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        seq_len: usize,
        rows: &[crate::runtime::engine::RowStep],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        // One routing decision per composed dispatch: every row of a
        // composed step lands on the same replica (artifact affinity makes
        // consecutive steps of the same family resume there too).
        self.dispatch(artifact, |exec| exec.step_rows_into(artifact, tokens, seq_len, rows, out))
    }

    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>> {
        self.dispatch(artifact, |exec| exec.draft(artifact, noise))
    }

    fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        // Metadata is replica-independent (every replica shares the
        // manifest) and, for engine replicas, served without touching the
        // engine thread — so no routing and no health check.
        let exec = self.inner.replicas[0].state.lock().unwrap().exec.clone();
        exec.meta(artifact)
    }

    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        scratch: &mut LoopScratch,
    ) -> Result<LoopReport> {
        // EngineHandle::run_loop *moves* the token storage into the engine
        // channel; if that replica dies mid-flight the tokens are gone
        // with it. A single replica has nowhere to re-route, so skip the
        // backup entirely (on error, tokens content is unspecified per
        // the trait contract).
        if self.replicas() == 1 {
            return self.dispatch(&spec.artifact, |exec| exec.run_loop(spec, tokens, scratch));
        }
        // Multi-replica: snapshot the init tokens into a persistent
        // per-thread buffer. `clone_from` reuses its capacity, so
        // steady-state runs on long-lived REFINE workers copy without
        // allocating (the PR 1 scratch contract, kept).
        RUN_LOOP_BACKUP.with(|cell| {
            let mut backup = cell.borrow_mut();
            backup.clone_from(tokens);
            let mut first = true;
            self.dispatch(&spec.artifact, |exec| {
                if !first {
                    tokens.clone_from(&backup);
                }
                first = false;
                exec.run_loop(spec, tokens, scratch)
            })
        })
    }
}

thread_local! {
    /// Init-token backup for [`FleetHandle::run_loop`]'s re-route path.
    /// Thread-local (not per-fleet) because a dispatch thread runs one
    /// loop at a time; capacity persists across runs.
    static RUN_LOOP_BACKUP: std::cell::RefCell<Vec<i32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::TestExec;
    use crate::runtime::engine::testsupport::{wedged_handle, WedgeCtl};
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::sync::atomic::AtomicUsize;

    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: vec![],
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
            schema_version: 1,
        }
    }

    /// An engine handle whose thread has been deliberately killed: every
    /// call observes the disconnected channel as the typed EngineDead
    /// (requests are FIFO, so anything sent after Shutdown fails).
    fn dead_engine() -> EngineHandle {
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        h.shutdown();
        h
    }

    fn mock() -> TestExec {
        TestExec::drift(vec![1, 4], 2, 4, 1)
    }

    /// Fast respawn schedule for tests: near-immediate retries.
    fn fast_robustness() -> RobustnessConfig {
        RobustnessConfig {
            respawn_backoff_ms: 1,
            respawn_backoff_cap_ms: 5,
            max_respawns: 5,
            ..RobustnessConfig::default()
        }
    }

    /// Spin until `cond` holds (5 s cap — generous; failure hangs are
    /// what this module exists to prevent).
    fn wait_for(what: &str, cond: impl Fn() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn single_replica_delegates_and_tracks_metrics() {
        let fleet = FleetHandle::from_executors(vec![Arc::new(mock()) as Arc<dyn Executor>]);
        assert_eq!(fleet.replicas(), 1);
        assert_eq!(fleet.healthy_replicas(), 1);
        let mut out = Vec::new();
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert_eq!(fleet.meta("mock_cold_step_b4").unwrap().batch, 4);
        let m = fleet.metrics();
        assert_eq!(m.replica_dispatched[0].get(), 1);
        assert_eq!(m.replica_inflight[0].get(), 0, "inflight released after the call");
        assert_eq!(m.fleet_reroutes.get(), 0);
    }

    #[test]
    fn affinity_prefers_replica_that_already_has_the_artifact() {
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(mock()) as Arc<dyn Executor>,
            Arc::new(mock()) as Arc<dyn Executor>,
        ]);
        let a = "mock_cold_step_b1";
        let b = "mock_warm_step_b1";
        let toks = [0i32; 2];
        let mut out = Vec::new();
        // Idle fleet, nothing compiled: lowest index wins -> replica 0.
        fleet.step_into(a, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[0].get(), 1);
        // Replica 0 busy: artifact b lands on replica 1 (least-loaded).
        fleet.metrics().replica_inflight[0].inc();
        fleet.step_into(b, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[1].get(), 1);
        fleet.metrics().replica_inflight[0].dec();
        // Idle again: b sticks to replica 1 by affinity despite the
        // higher index; a sticks to replica 0.
        fleet.step_into(b, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[1].get(), 2);
        fleet.step_into(a, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[0].get(), 2);
        assert_eq!(fleet.metrics().fleet_reroutes.get(), 0);
    }

    #[test]
    fn dead_replica_quarantined_and_call_rerouted() {
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(mock()) as Arc<dyn Executor>,
        ]);
        let mut out = Vec::new();
        // Routed to replica 0 (idle, lowest index), which is dead: the
        // call must still succeed via replica 1.
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert_eq!(fleet.healthy_replicas(), 1);
        let m = fleet.metrics();
        assert_eq!(m.replica_unhealthy.get(), 1);
        assert_eq!(m.fleet_reroutes.get(), 1);
        assert_eq!(m.replica_dispatched[0].get(), 1);
        assert_eq!(m.replica_dispatched[1].get(), 1);
        // The quarantined replica is never picked again; routing around a
        // known-dead replica is not a re-route.
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(m.replica_dispatched[0].get(), 1);
        assert_eq!(m.replica_dispatched[1].get(), 2);
        assert_eq!(m.fleet_reroutes.get(), 1);
        assert!(fleet.summary().contains("(unhealthy)"), "{}", fleet.summary());
    }

    #[test]
    fn all_replicas_down_is_typed_fleet_down_not_a_hang() {
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(dead_engine()) as Arc<dyn Executor>,
        ]);
        let mut out = Vec::new();
        let err =
            fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap_err();
        assert!(err.downcast_ref::<FleetDown>().is_some(), "{err:#}");
        assert_eq!(fleet.healthy_replicas(), 0);
        assert_eq!(fleet.metrics().replica_unhealthy.get(), 2);
        // Subsequent calls fail fast with the same typed error.
        let err2 = fleet.draft("a", &[0.0]).unwrap_err();
        assert!(err2.downcast_ref::<FleetDown>().is_some(), "{err2:#}");
    }

    #[test]
    fn run_loop_reroute_restores_init_tokens() {
        // The engine protocol moves token storage into the channel; a
        // death mid-dispatch must not corrupt the retried run. A
        // stochastic mock makes the output depend on the init tokens, so
        // equality with a direct solo run proves the backup restored them.
        let spec = LoopSpec::full("mock_cold_step_b4".into(), 10, 0.5, 1.0, 7, false);
        let solo = TestExec::stochastic(vec![1, 4], 2, 4, 1);
        let mut expected = vec![3i32; 8];
        solo.run_loop(&spec, &mut expected, &mut LoopScratch::default()).unwrap();

        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(TestExec::stochastic(vec![1, 4], 2, 4, 1)) as Arc<dyn Executor>,
        ]);
        let mut tokens = vec![3i32; 8];
        let mut scratch = LoopScratch::default();
        let report = fleet.run_loop(&spec, &mut tokens, &mut scratch).unwrap();
        assert_eq!(report.nfe, 5);
        assert_eq!(tokens, expected, "rerouted run must see the original init tokens");
        assert_eq!(fleet.metrics().fleet_reroutes.get(), 1);
    }

    #[test]
    fn preload_quarantines_dead_replicas_instead_of_aborting() {
        let fleet = FleetHandle::spawn(empty_manifest(), 2).unwrap();
        fleet.preload(&[]).unwrap(); // live engines, nothing to compile
        assert_eq!(fleet.healthy_replicas(), 2);
        fleet.shutdown();
        // Every engine dead: preload quarantines them (failure isolation,
        // same contract as dispatch) and reports the typed FleetDown
        // rather than a hard per-replica error.
        let err = fleet.preload(&[]).unwrap_err();
        assert!(err.downcast_ref::<FleetDown>().is_some(), "{err:#}");
        assert_eq!(fleet.healthy_replicas(), 0);
        assert_eq!(fleet.metrics().replica_unhealthy.get(), 2);
    }

    #[test]
    fn engine_backed_fleet_summary_and_shutdown() {
        let fleet = FleetHandle::spawn(empty_manifest(), 2).unwrap();
        assert_eq!(fleet.replicas(), 2);
        let s = fleet.summary();
        assert!(s.contains("replicas=2"), "{s}");
        assert!(s.contains("replica 0:") && s.contains("replica 1:"), "{s}");
        assert!(s.contains("compiled"), "{s}");
        assert_eq!(fleet.engine_stats().iter().filter(|e| e.is_some()).count(), 2);
        fleet.shutdown();
        // Replicas floored at 1: a zero-replica config still serves.
        let one = FleetHandle::spawn(empty_manifest(), 0).unwrap();
        assert_eq!(one.replicas(), 1);
        one.shutdown();
    }

    #[test]
    fn wedged_replica_trips_timeout_quarantine_and_late_reply_is_discarded() {
        // Replica 0 is a real EngineHandle over a wedged serving thread,
        // watchdog armed at 40 ms. The dispatched call must (a) trip the
        // typed EngineTimeout within the deadline, (b) quarantine + re-
        // route to replica 1 and still succeed, and (c) leave the wedged
        // engine's eventual late reply with no receiver.
        let ctl = WedgeCtl::new();
        let wedged = wedged_handle(empty_manifest(), ctl.clone())
            .with_call_timeout(Some(Duration::from_millis(40)));
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(wedged) as Arc<dyn Executor>,
            Arc::new(mock()) as Arc<dyn Executor>,
        ]);
        let start = Instant::now();
        let mut out = Vec::new();
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert!(start.elapsed() < Duration::from_secs(5), "watchdog did not bound the wait");
        assert_eq!(out.len(), 8 * 4);
        let m = fleet.metrics();
        assert_eq!(m.engine_timeouts.get(), 1);
        assert_eq!(m.replica_unhealthy.get(), 1);
        assert_eq!(m.fleet_reroutes.get(), 1);
        assert_eq!(fleet.healthy_replicas(), 1);
        // Un-wedge: the parked reply is sent late — to a dropped channel.
        ctl.release();
        wait_for("the wedged engine's late reply", || ctl.late_sends() >= 1);
        assert_eq!(ctl.late_delivered(), 0, "stale late reply reached a live receiver");
    }

    #[test]
    fn killed_engine_replica_is_resurrected_and_serves_traffic_again() {
        let fleet = FleetHandle::spawn_with(empty_manifest(), 2, &fast_robustness()).unwrap();
        assert_eq!(fleet.healthy_replicas(), 2);
        fleet.kill_replica(0);
        assert_eq!(fleet.healthy_replicas(), 1);
        assert_eq!(fleet.metrics().replica_unhealthy.get(), 1);
        // The health loop respawns a fresh engine thread, probes it, and
        // readmits the slot.
        wait_for("replica 0 resurrection", || fleet.healthy_replicas() == 2);
        assert!(fleet.metrics().replica_respawns.get() >= 1);
        // It serves traffic again: the next dispatch routes to replica 0
        // (idle, lowest index) and fails with an *ordinary* error on the
        // empty manifest — a live engine answering, not EngineDead, not
        // FleetDown.
        let err = fleet.draft("nope", &[0.0]).unwrap_err();
        assert!(err.downcast_ref::<FleetDown>().is_none(), "{err:#}");
        assert!(err.downcast_ref::<EngineDead>().is_none(), "{err:#}");
        assert_eq!(fleet.metrics().replica_dispatched[0].get(), 1);
        assert_eq!(fleet.healthy_replicas(), 2, "an ordinary error must not re-quarantine");
        fleet.shutdown();
    }

    #[test]
    fn factory_replica_resurrected_with_a_fresh_build() {
        let builds = Arc::new(AtomicUsize::new(0));
        let factory = |builds: Arc<AtomicUsize>| -> ReplicaFactory {
            Box::new(move || {
                builds.fetch_add(1, Ordering::SeqCst);
                Ok(Arc::new(TestExec::drift(vec![1, 4], 2, 4, 1)) as Arc<dyn Executor>)
            })
        };
        let fleet = FleetHandle::from_factories(
            vec![factory(builds.clone()), factory(builds.clone())],
            &fast_robustness(),
        )
        .unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 2);
        fleet.kill_replica(1);
        assert_eq!(fleet.healthy_replicas(), 1);
        wait_for("replica 1 resurrection", || fleet.healthy_replicas() == 2);
        assert_eq!(builds.load(Ordering::SeqCst), 3, "resurrection must build a fresh executor");
        assert_eq!(fleet.metrics().replica_respawns.get(), 1);
        // The resurrected slot takes traffic: saturate replica 0 and
        // dispatch — least-loaded routing picks replica 1.
        fleet.metrics().replica_inflight[0].inc();
        let mut out = Vec::new();
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[1].get(), 1);
        fleet.metrics().replica_inflight[0].dec();
        fleet.shutdown();
    }

    #[test]
    fn stale_generation_failure_cannot_quarantine_a_resurrected_replica() {
        let factory = || -> ReplicaFactory {
            Box::new(|| Ok(Arc::new(TestExec::drift(vec![1, 4], 2, 4, 1)) as Arc<dyn Executor>))
        };
        let fleet =
            FleetHandle::from_factories(vec![factory(), factory()], &fast_robustness()).unwrap();
        fleet.kill_replica(0);
        wait_for("replica 0 resurrection", || fleet.healthy_replicas() == 2);
        let unhealthy_before = fleet.metrics().replica_unhealthy.get();
        // A call that started on generation 0 reports its failure only
        // now — after the slot moved to generation 1. It must be inert.
        fleet.quarantine(0, 0);
        assert_eq!(fleet.healthy_replicas(), 2, "stale failure quarantined the new replica");
        assert_eq!(fleet.metrics().replica_unhealthy.get(), unhealthy_before);
        // The same failure reported against the *current* generation
        // quarantines as usual.
        fleet.quarantine(0, 1);
        assert_eq!(fleet.healthy_replicas(), 1);
        fleet.shutdown();
    }

    #[test]
    fn respawn_circuit_breaker_retires_after_consecutive_failures() {
        // Initial builds succeed; every respawn fails. With
        // max_respawns = 2 the health loop must try exactly twice, then
        // retire the slot permanently.
        let builds = Arc::new(AtomicUsize::new(0));
        let factory = |builds: Arc<AtomicUsize>, initial_ok: usize| -> ReplicaFactory {
            Box::new(move || {
                let n = builds.fetch_add(1, Ordering::SeqCst);
                if n < initial_ok {
                    Ok(Arc::new(TestExec::drift(vec![1, 4], 2, 4, 1)) as Arc<dyn Executor>)
                } else {
                    anyhow::bail!("replacement hardware not available")
                }
            })
        };
        let rb = RobustnessConfig {
            respawn_backoff_ms: 1,
            respawn_backoff_cap_ms: 2,
            max_respawns: 2,
            ..RobustnessConfig::default()
        };
        let fleet = FleetHandle::from_factories(
            vec![factory(builds.clone(), 2), factory(builds.clone(), 2)],
            &rb,
        )
        .unwrap();
        fleet.kill_replica(1);
        wait_for("both respawn attempts to fail", || {
            fleet.metrics().respawn_failures.get() >= 2
        });
        // Retired: no further attempts, the slot stays quarantined.
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(fleet.metrics().respawn_failures.get(), 2, "circuit breaker kept retrying");
        assert_eq!(fleet.metrics().replica_respawns.get(), 0);
        assert_eq!(fleet.healthy_replicas(), 1);
        // The surviving replica still serves.
        let mut out = Vec::new();
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        fleet.shutdown();
    }

    #[test]
    fn swap_artifacts_moves_every_replica_to_the_new_epoch() {
        let fleet = FleetHandle::spawn_with(empty_manifest(), 2, &fast_robustness()).unwrap();
        assert_eq!(fleet.manifest_epochs(), vec![0, 0]);
        fleet.swap_artifacts(empty_manifest()).unwrap();
        assert_eq!(fleet.manifest_epochs(), vec![1, 1]);
        assert_eq!(fleet.healthy_replicas(), 2);
        assert_eq!(fleet.metrics().artifact_swaps.get(), 1);
        assert_eq!(fleet.metrics().artifact_swap_rollbacks.get(), 0);
        // The swapped-in engines serve: an unknown artifact gets an
        // ordinary error from a live engine, not EngineDead/FleetDown.
        let err = fleet.draft("nope", &[0.0]).unwrap_err();
        assert!(err.downcast_ref::<EngineDead>().is_none(), "{err:#}");
        assert!(err.downcast_ref::<FleetDown>().is_none(), "{err:#}");
        assert!(fleet.summary().contains("artifact_swaps=1"), "{}", fleet.summary());
        fleet.shutdown();
    }

    #[test]
    fn swap_rejects_hash_mismatch_with_the_old_fleet_untouched() {
        use crate::core::rng::{fnv1a64, FNV_OFFSET};
        let dir = std::env::temp_dir().join(format!("wsfm_swap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), b"actual bytes").unwrap();
        let wrong = fnv1a64(FNV_OFFSET, b"different bytes");
        let bad = Manifest {
            dir: dir.clone(),
            artifacts: vec![ArtifactMeta {
                name: "a".into(),
                hlo_file: "a.hlo.txt".into(),
                domain: "d".into(),
                kind: "step".into(),
                tag: "cold".into(),
                draft: None,
                batch: 1,
                seq_len: 1,
                vocab: 2,
                t0: Some(0.0),
                latent_dim: None,
                inputs: vec![],
                outputs: vec![],
                content_hash: Some(wrong),
            }],
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
            schema_version: 2,
        };
        let fleet = FleetHandle::spawn(empty_manifest(), 2).unwrap();
        let err = fleet.swap_artifacts(bad).unwrap_err();
        assert!(format!("{err:#}").contains("content hash mismatch"), "{err:#}");
        // Nothing moved: old epoch, old engines, still serving.
        assert_eq!(fleet.manifest_epochs(), vec![0, 0]);
        assert_eq!(fleet.healthy_replicas(), 2);
        assert_eq!(fleet.metrics().artifact_swaps.get(), 0);
        assert_eq!(fleet.metrics().artifact_swap_rollbacks.get(), 1);
        fleet.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn swap_rejects_non_engine_fleets_before_building_anything() {
        let fleet = FleetHandle::from_executors(vec![Arc::new(mock()) as Arc<dyn Executor>]);
        let err = fleet.swap_artifacts(empty_manifest()).unwrap_err();
        assert!(format!("{err:#}").contains("not engine-backed"), "{err:#}");
        assert_eq!(fleet.metrics().artifact_swap_rollbacks.get(), 1);
        assert_eq!(fleet.manifest_epochs(), vec![0]);
    }

    /// Acceptance pin: repeated swaps while a killer thread murders
    /// replicas (and the health loop resurrects them) must end every
    /// swap with a **uniform** fleet — all replicas on the published
    /// epoch, never mixed old/new contracts.
    #[test]
    fn swap_under_killed_replica_chaos_never_yields_a_mixed_fleet() {
        const REPLICAS: usize = 3;
        const SWAPS: u64 = 4;
        let fleet = FleetHandle::spawn_with(empty_manifest(), REPLICAS, &fast_robustness()).unwrap();
        let stop_killing = Arc::new(AtomicBool::new(false));
        let killer = {
            let fleet = fleet.clone();
            let stop = stop_killing.clone();
            std::thread::spawn(move || {
                let mut i = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    fleet.kill_replica(i % REPLICAS);
                    i += 1;
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        };
        for swap in 1..=SWAPS {
            fleet.swap_artifacts(empty_manifest()).unwrap();
            let epochs = fleet.manifest_epochs();
            // The killer may quarantine a replica right after publication,
            // but it can never split the *contract*: every slot carries
            // the epoch this swap stamped.
            assert!(
                epochs.iter().all(|&e| e == swap),
                "mixed fleet after swap {swap}: {epochs:?}"
            );
        }
        stop_killing.store(true, Ordering::SeqCst);
        killer.join().unwrap();
        // Let the health loop repair any post-swap kill, then confirm the
        // fleet is whole and uniform on the final epoch.
        wait_for("post-chaos resurrection", || fleet.healthy_replicas() == REPLICAS);
        let epochs = fleet.manifest_epochs();
        assert!(epochs.iter().all(|&e| e == SWAPS), "post-chaos mixed fleet: {epochs:?}");
        assert_eq!(fleet.metrics().artifact_swaps.get(), SWAPS);
        assert_eq!(fleet.metrics().artifact_swap_rollbacks.get(), 0);
        fleet.shutdown();
    }

    #[test]
    fn attached_obs_journals_lifecycle_events_and_engine_call_spans() {
        // One dead replica + one live one, a scope open as the scheduler
        // would: the rerouted dispatch must journal Quarantine and
        // Reroute events exactly 1:1 with the counters, tag engine-call
        // spans with the ambient bundle id and replica index, and leave
        // the replica/reroute trail in the scope.
        let obs = Arc::new(Obs::default());
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(mock()) as Arc<dyn Executor>,
        ]);
        fleet.attach_obs(obs.clone());
        let prev = scope::begin(99);
        let mut out = Vec::new();
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        let trail = scope::end(prev).expect("scope was open");
        let m = fleet.metrics();
        assert_eq!(
            obs.events.of_kind(EventKind::Quarantine).len() as u64,
            m.replica_unhealthy.get()
        );
        assert_eq!(obs.events.of_kind(EventKind::Reroute).len() as u64, m.fleet_reroutes.get());
        assert_eq!(obs.events.of_kind(EventKind::Quarantine)[0].replica, Some(0));
        assert_eq!(obs.events.of_kind(EventKind::Reroute)[0].replica, Some(1));
        assert_eq!(trail.replicas, vec![0, 1], "both attempts left the dispatch trail");
        assert_eq!(trail.reroutes, 1);
        let spans = obs.spans.for_request(0); // bundle-scoped spans join via bundle 99
        let call_replicas: Vec<u32> =
            spans.iter().filter(|s| s.kind == SpanKind::EngineCall).map(|s| s.detail).collect();
        assert_eq!(call_replicas, vec![0, 1], "one span per attempt, detail = replica");
        assert!(spans.iter().all(|s| s.bundle_id == 99), "ambient bundle id rode the scope");
        // Unattached fleets record nothing (the pre-PR-9 behaviour).
        let bare = FleetHandle::from_executors(vec![Arc::new(mock()) as Arc<dyn Executor>]);
        bare.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(obs.spans.for_request(0).len(), spans.len());
    }

    #[test]
    fn stress_route_claim_vs_quarantine_race_conserves_accounting() {
        // Satellite: N dispatcher threads drive a 2-replica fleet while a
        // killer thread repeatedly murders replica 1 and the health loop
        // resurrects it. Invariants: every call resolves (success or
        // typed error — no hangs, joined below), every inflight gauge
        // returns to zero, and the dispatch accounting is conserved:
        // every claim incremented exactly one dispatched counter, and
        // every extra attempt was counted as a reroute, so
        // sum(dispatched) == resolved calls + reroutes.
        const THREADS: usize = 4;
        const CALLS: usize = 25;
        let factory = || -> ReplicaFactory {
            Box::new(|| Ok(Arc::new(TestExec::drift(vec![1, 4], 2, 4, 1)) as Arc<dyn Executor>))
        };
        let fleet =
            FleetHandle::from_factories(vec![factory(), factory()], &fast_robustness()).unwrap();

        let killer = {
            let fleet = fleet.clone();
            std::thread::spawn(move || {
                for _ in 0..10 {
                    fleet.kill_replica(1);
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        };
        let dispatchers: Vec<_> = (0..THREADS)
            .map(|_| {
                let fleet = fleet.clone();
                std::thread::spawn(move || {
                    let mut ok = 0usize;
                    let mut out = Vec::new();
                    for _ in 0..CALLS {
                        if fleet
                            .step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out)
                            .is_ok()
                        {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        let ok: usize = dispatchers.into_iter().map(|d| d.join().unwrap()).sum();
        killer.join().unwrap();

        // TestExec replicas never fail, so even mid-kill calls succeed —
        // the kill only flips routing state. Every call resolved.
        assert_eq!(ok, THREADS * CALLS, "calls were lost under kill/resurrect churn");
        let m = fleet.metrics();
        for (i, g) in m.replica_inflight.iter().enumerate() {
            assert_eq!(g.get(), 0, "replica {i} inflight gauge leaked");
        }
        let dispatched: u64 = m.replica_dispatched.iter().map(|c| c.get()).sum();
        assert_eq!(
            dispatched,
            (THREADS * CALLS) as u64 + m.fleet_reroutes.get(),
            "dispatch accounting not conserved"
        );
        fleet.shutdown();
    }
}
