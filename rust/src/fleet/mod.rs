//! The engine fleet: a replicated executor pool behind one routing handle.
//!
//! The paper's speed-up is per-sample NFE, but a single engine thread is
//! still one execution stream: concurrent bundles serialize on it no
//! matter how many pipeline stages feed it. [`FleetHandle`] spawns `N`
//! full engine replicas — each its own engine thread **and** artifact
//! cache ([`crate::runtime::EngineHandle`]) — and implements [`Executor`]
//! itself, so everything that talks to "the engine" (scheduler, sampler,
//! REFINE workers, benches) transparently talks to the fleet instead.
//! `fleet.replicas = 1` (the default) is today's single-engine behaviour
//! verbatim: one engine thread, one cache, every call routed to it.
//!
//! ## Routing
//!
//! Dispatch is deterministic least-loaded with artifact affinity
//! ([`router`]): healthy replicas only, fewest in-flight calls first,
//! affinity (the replica already holds the artifact's compiled
//! executable) breaking load ties, lowest index breaking the rest. The
//! route+claim step runs under a lock so concurrent dispatchers observe
//! each other's in-flight increments — two idle-fleet dispatches land on
//! two different replicas, never stampede one.
//!
//! ## Failure isolation
//!
//! A replica whose engine thread dies surfaces the typed
//! [`EngineDead`] error (never a hang). The fleet quarantines it
//! (`replica_unhealthy`), re-routes the failed call to another healthy
//! replica (`fleet_reroutes`, with the run's init tokens restored from a
//! backup for `run_loop`, whose engine protocol moves token storage), and
//! surfaces the typed [`FleetDown`] error once no healthy replica
//! remains. Replica deaths are independent: one panicked engine thread
//! never takes the fleet down.
//!
//! ## Determinism
//!
//! Outputs are a pure function of `(config seed, bundle)` — the stateless
//! RNG substream contract established by the engine-resident loop and the
//! pipelined coordinator — so *which* replica refines a bundle can never
//! change its tokens. Bitwise-identical outputs across
//! `fleet.replicas × fleet.refine_workers` sweeps are pinned by the
//! coordinator's determinism tests.

pub mod router;

use crate::fleet::router::{route, Candidate};
use crate::metrics::FleetMetrics;
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::runtime::engine::{
    EngineDead, EngineHandle, EngineStats, Executor, LoopReport, LoopScratch, LoopSpec,
};
use anyhow::{Context, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Typed error surfaced when every replica in the fleet is unhealthy:
/// callers get a fast, downcastable failure instead of a hang or a
/// generic channel error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetDown;

impl std::fmt::Display for FleetDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "all fleet replicas are down")
    }
}

impl std::error::Error for FleetDown {}

/// One replica slot: the executor, its health flag, and the set of
/// artifacts it has been sent (its compile-cache shadow, for affinity).
struct Replica {
    exec: Arc<dyn Executor>,
    /// Engine-backed replicas keep the handle for preload/stats/shutdown.
    engine: Option<EngineHandle>,
    healthy: AtomicBool,
    artifacts: Mutex<HashSet<String>>,
}

struct FleetInner {
    replicas: Vec<Replica>,
    metrics: FleetMetrics,
    /// Serializes route+claim so concurrent dispatchers see each other's
    /// in-flight increments (without it, two simultaneous dispatches on an
    /// idle fleet would both pick replica 0).
    router_lock: Mutex<()>,
}

/// Cloneable, thread-safe front-end to the replica pool; implements
/// [`Executor`] so it drops in anywhere an engine handle does.
#[derive(Clone)]
pub struct FleetHandle {
    inner: Arc<FleetInner>,
}

impl FleetHandle {
    /// Spawn `replicas` engine replicas over a manifest (each its own
    /// engine thread + artifact cache). `replicas` is floored at 1.
    pub fn spawn(manifest: Manifest, replicas: usize) -> Result<FleetHandle> {
        let n = replicas.max(1);
        let mut slots = Vec::with_capacity(n);
        for i in 0..n {
            let engine = EngineHandle::spawn(manifest.clone())
                .with_context(|| format!("spawning fleet replica {i}"))?;
            slots.push(Replica {
                exec: Arc::new(engine.clone()),
                engine: Some(engine),
                healthy: AtomicBool::new(true),
                artifacts: Mutex::new(HashSet::new()),
            });
        }
        Ok(FleetHandle::from_slots(slots))
    }

    /// Build a fleet over arbitrary executors (tests, benches: mock
    /// replicas with controlled behaviour). Panics on an empty pool.
    pub fn from_executors(execs: Vec<Arc<dyn Executor>>) -> FleetHandle {
        let slots = execs
            .into_iter()
            .map(|exec| Replica {
                exec,
                engine: None,
                healthy: AtomicBool::new(true),
                artifacts: Mutex::new(HashSet::new()),
            })
            .collect();
        FleetHandle::from_slots(slots)
    }

    fn from_slots(slots: Vec<Replica>) -> FleetHandle {
        assert!(!slots.is_empty(), "fleet needs at least one replica");
        let metrics = FleetMetrics::new(slots.len());
        FleetHandle {
            inner: Arc::new(FleetInner { replicas: slots, metrics, router_lock: Mutex::new(()) }),
        }
    }

    /// Total replicas (healthy or not).
    pub fn replicas(&self) -> usize {
        self.inner.replicas.len()
    }

    /// Replicas still accepting work.
    pub fn healthy_replicas(&self) -> usize {
        self.inner.replicas.iter().filter(|r| r.healthy.load(Ordering::SeqCst)).count()
    }

    /// The fleet's routing/health metrics (per-replica inflight gauges,
    /// unhealthy + reroute counters).
    pub fn metrics(&self) -> &FleetMetrics {
        &self.inner.metrics
    }

    /// Route + claim a replica for `artifact` under the router lock:
    /// increments its inflight gauge and records the artifact in its
    /// affinity set before releasing the lock.
    fn claim(&self, artifact: &str) -> Result<usize> {
        let m = &self.inner.metrics;
        let _g = self.inner.router_lock.lock().unwrap();
        let candidates: Vec<Candidate> = self
            .inner
            .replicas
            .iter()
            .enumerate()
            .map(|(index, r)| Candidate {
                index,
                healthy: r.healthy.load(Ordering::SeqCst),
                inflight: m.replica_inflight[index].get(),
                has_artifact: r.artifacts.lock().unwrap().contains(artifact),
            })
            .collect();
        let idx = route(&candidates).ok_or_else(|| anyhow::Error::new(FleetDown))?;
        m.replica_inflight[idx].inc();
        m.replica_dispatched[idx].inc();
        // candidates[idx].index == idx (built in order); skip the String
        // allocation + re-lock once the artifact is known resident.
        if !candidates[idx].has_artifact {
            self.inner.replicas[idx].artifacts.lock().unwrap().insert(artifact.to_string());
        }
        Ok(idx)
    }

    /// Run `call` on the routed replica. On the typed [`EngineDead`]
    /// error the replica is quarantined and the call re-routed; every
    /// other error (bad artifact, shape mismatch) returns unchanged —
    /// it would fail identically anywhere. Each death permanently removes
    /// one candidate, so the loop is bounded by the replica count before
    /// [`claim`](Self::claim) surfaces [`FleetDown`].
    fn dispatch<T>(
        &self,
        artifact: &str,
        mut call: impl FnMut(&dyn Executor) -> Result<T>,
    ) -> Result<T> {
        let m = &self.inner.metrics;
        let mut attempt = 0usize;
        loop {
            let idx = self.claim(artifact)?;
            if attempt > 0 {
                m.fleet_reroutes.inc();
            }
            attempt += 1;
            let replica = &self.inner.replicas[idx];
            let result = call(&*replica.exec);
            m.replica_inflight[idx].dec();
            match result {
                Err(e) if e.downcast_ref::<EngineDead>().is_some() => {
                    // swap() keeps the unhealthy counter exact when two
                    // in-flight calls observe the same death.
                    if replica.healthy.swap(false, Ordering::SeqCst) {
                        m.replica_unhealthy.inc();
                        crate::error!("fleet: replica {idx} engine died; re-routing its work");
                    }
                }
                other => return other,
            }
        }
    }

    /// Eagerly compile `names` on **every** engine-backed replica.
    /// Duplicate compilation is deliberate here — preload is the operator
    /// buying compile time up front so no replica pays it on the request
    /// path — and the affinity sets are updated to match. A replica that
    /// answers with [`EngineDead`] is quarantined, not fatal (the same
    /// failure-isolation contract as dispatch: one dead engine never
    /// takes the fleet down); ordinary compile errors still propagate,
    /// and an entirely dead pool surfaces [`FleetDown`].
    pub fn preload(&self, names: &[String]) -> Result<()> {
        for (i, r) in self.inner.replicas.iter().enumerate() {
            let Some(engine) = &r.engine else { continue };
            if !r.healthy.load(Ordering::SeqCst) {
                continue;
            }
            match engine.preload(names) {
                Ok(()) => r.artifacts.lock().unwrap().extend(names.iter().cloned()),
                Err(e) if e.downcast_ref::<EngineDead>().is_some() => {
                    if r.healthy.swap(false, Ordering::SeqCst) {
                        self.inner.metrics.replica_unhealthy.inc();
                        crate::error!("fleet: replica {i} engine died during preload; quarantined");
                    }
                }
                Err(e) => return Err(e.context(format!("preloading fleet replica {i}"))),
            }
        }
        if self.healthy_replicas() == 0 {
            return Err(anyhow::Error::new(FleetDown));
        }
        Ok(())
    }

    /// Per-replica engine statistics (`None` for non-engine replicas and
    /// for dead engines).
    pub fn engine_stats(&self) -> Vec<Option<EngineStats>> {
        self.inner
            .replicas
            .iter()
            .map(|r| r.engine.as_ref().and_then(|e| e.stats().ok()))
            .collect()
    }

    /// Multi-line human summary for the serve/selfcheck CLI: the fleet
    /// counters plus one line per replica.
    pub fn summary(&self) -> String {
        let mut s = self.inner.metrics.summary();
        for (i, r) in self.inner.replicas.iter().enumerate() {
            let health = if r.healthy.load(Ordering::SeqCst) { "" } else { " (unhealthy)" };
            match &r.engine {
                Some(engine) => match engine.stats() {
                    Ok(es) => s.push_str(&format!("\n  replica {i}{health}: {}", es.summary())),
                    Err(_) => s.push_str(&format!("\n  replica {i}{health}: engine dead")),
                },
                None => s.push_str(&format!("\n  replica {i}{health}: (non-engine executor)")),
            }
        }
        s
    }

    /// Shut down every engine-backed replica.
    pub fn shutdown(&self) {
        for r in &self.inner.replicas {
            if let Some(engine) = &r.engine {
                engine.shutdown();
            }
        }
    }
}

impl Executor for FleetHandle {
    fn step_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.dispatch(artifact, |exec| exec.step_into(artifact, tokens, t, h, warp, out))
    }

    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>> {
        self.dispatch(artifact, |exec| exec.draft(artifact, noise))
    }

    fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        // Metadata is replica-independent (every replica shares the
        // manifest) and, for engine replicas, served without touching the
        // engine thread — so no routing and no health check.
        self.inner.replicas[0].exec.meta(artifact)
    }

    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        scratch: &mut LoopScratch,
    ) -> Result<LoopReport> {
        // EngineHandle::run_loop *moves* the token storage into the engine
        // channel; if that replica dies mid-flight the tokens are gone
        // with it. A single replica has nowhere to re-route, so skip the
        // backup entirely (on error, tokens content is unspecified per
        // the trait contract).
        if self.replicas() == 1 {
            return self.dispatch(&spec.artifact, |exec| exec.run_loop(spec, tokens, scratch));
        }
        // Multi-replica: snapshot the init tokens into a persistent
        // per-thread buffer. `clone_from` reuses its capacity, so
        // steady-state runs on long-lived REFINE workers copy without
        // allocating (the PR 1 scratch contract, kept).
        RUN_LOOP_BACKUP.with(|cell| {
            let mut backup = cell.borrow_mut();
            backup.clone_from(tokens);
            let mut first = true;
            self.dispatch(&spec.artifact, |exec| {
                if !first {
                    tokens.clone_from(&backup);
                }
                first = false;
                exec.run_loop(spec, tokens, scratch)
            })
        })
    }
}

thread_local! {
    /// Init-token backup for [`FleetHandle::run_loop`]'s re-route path.
    /// Thread-local (not per-fleet) because a dispatch thread runs one
    /// loop at a time; capacity persists across runs.
    static RUN_LOOP_BACKUP: std::cell::RefCell<Vec<i32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::TestExec;
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn empty_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::from("/tmp"),
            artifacts: vec![],
            domains: Json::Null,
            batch_sizes: BTreeMap::new(),
        }
    }

    /// An engine handle whose thread has been deliberately killed: every
    /// call observes the disconnected channel as the typed EngineDead
    /// (requests are FIFO, so anything sent after Shutdown fails).
    fn dead_engine() -> EngineHandle {
        let h = EngineHandle::spawn(empty_manifest()).unwrap();
        h.shutdown();
        h
    }

    fn mock() -> TestExec {
        TestExec::drift(vec![1, 4], 2, 4, 1)
    }

    #[test]
    fn single_replica_delegates_and_tracks_metrics() {
        let fleet = FleetHandle::from_executors(vec![Arc::new(mock()) as Arc<dyn Executor>]);
        assert_eq!(fleet.replicas(), 1);
        assert_eq!(fleet.healthy_replicas(), 1);
        let mut out = Vec::new();
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert_eq!(fleet.meta("mock_cold_step_b4").unwrap().batch, 4);
        let m = fleet.metrics();
        assert_eq!(m.replica_dispatched[0].get(), 1);
        assert_eq!(m.replica_inflight[0].get(), 0, "inflight released after the call");
        assert_eq!(m.fleet_reroutes.get(), 0);
    }

    #[test]
    fn affinity_prefers_replica_that_already_has_the_artifact() {
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(mock()) as Arc<dyn Executor>,
            Arc::new(mock()) as Arc<dyn Executor>,
        ]);
        let a = "mock_cold_step_b1";
        let b = "mock_warm_step_b1";
        let toks = [0i32; 2];
        let mut out = Vec::new();
        // Idle fleet, nothing compiled: lowest index wins -> replica 0.
        fleet.step_into(a, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[0].get(), 1);
        // Replica 0 busy: artifact b lands on replica 1 (least-loaded).
        fleet.metrics().replica_inflight[0].inc();
        fleet.step_into(b, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[1].get(), 1);
        fleet.metrics().replica_inflight[0].dec();
        // Idle again: b sticks to replica 1 by affinity despite the
        // higher index; a sticks to replica 0.
        fleet.step_into(b, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[1].get(), 2);
        fleet.step_into(a, &toks, 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(fleet.metrics().replica_dispatched[0].get(), 2);
        assert_eq!(fleet.metrics().fleet_reroutes.get(), 0);
    }

    #[test]
    fn dead_replica_quarantined_and_call_rerouted() {
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(mock()) as Arc<dyn Executor>,
        ]);
        let mut out = Vec::new();
        // Routed to replica 0 (idle, lowest index), which is dead: the
        // call must still succeed via replica 1.
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert_eq!(fleet.healthy_replicas(), 1);
        let m = fleet.metrics();
        assert_eq!(m.replica_unhealthy.get(), 1);
        assert_eq!(m.fleet_reroutes.get(), 1);
        assert_eq!(m.replica_dispatched[0].get(), 1);
        assert_eq!(m.replica_dispatched[1].get(), 1);
        // The quarantined replica is never picked again; routing around a
        // known-dead replica is not a re-route.
        fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap();
        assert_eq!(m.replica_dispatched[0].get(), 1);
        assert_eq!(m.replica_dispatched[1].get(), 2);
        assert_eq!(m.fleet_reroutes.get(), 1);
        assert!(fleet.summary().contains("(unhealthy)"), "{}", fleet.summary());
    }

    #[test]
    fn all_replicas_down_is_typed_fleet_down_not_a_hang() {
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(dead_engine()) as Arc<dyn Executor>,
        ]);
        let mut out = Vec::new();
        let err =
            fleet.step_into("mock_cold_step_b4", &[0i32; 8], 0.0, 0.1, 1.0, &mut out).unwrap_err();
        assert!(err.downcast_ref::<FleetDown>().is_some(), "{err:#}");
        assert_eq!(fleet.healthy_replicas(), 0);
        assert_eq!(fleet.metrics().replica_unhealthy.get(), 2);
        // Subsequent calls fail fast with the same typed error.
        let err2 = fleet.draft("a", &[0.0]).unwrap_err();
        assert!(err2.downcast_ref::<FleetDown>().is_some(), "{err2:#}");
    }

    #[test]
    fn run_loop_reroute_restores_init_tokens() {
        // The engine protocol moves token storage into the channel; a
        // death mid-dispatch must not corrupt the retried run. A
        // stochastic mock makes the output depend on the init tokens, so
        // equality with a direct solo run proves the backup restored them.
        let spec = LoopSpec::full("mock_cold_step_b4".into(), 10, 0.5, 1.0, 7, false);
        let solo = TestExec::stochastic(vec![1, 4], 2, 4, 1);
        let mut expected = vec![3i32; 8];
        solo.run_loop(&spec, &mut expected, &mut LoopScratch::default()).unwrap();

        let fleet = FleetHandle::from_executors(vec![
            Arc::new(dead_engine()) as Arc<dyn Executor>,
            Arc::new(TestExec::stochastic(vec![1, 4], 2, 4, 1)) as Arc<dyn Executor>,
        ]);
        let mut tokens = vec![3i32; 8];
        let mut scratch = LoopScratch::default();
        let report = fleet.run_loop(&spec, &mut tokens, &mut scratch).unwrap();
        assert_eq!(report.nfe, 5);
        assert_eq!(tokens, expected, "rerouted run must see the original init tokens");
        assert_eq!(fleet.metrics().fleet_reroutes.get(), 1);
    }

    #[test]
    fn preload_quarantines_dead_replicas_instead_of_aborting() {
        let fleet = FleetHandle::spawn(empty_manifest(), 2).unwrap();
        fleet.preload(&[]).unwrap(); // live engines, nothing to compile
        assert_eq!(fleet.healthy_replicas(), 2);
        fleet.shutdown();
        // Every engine dead: preload quarantines them (failure isolation,
        // same contract as dispatch) and reports the typed FleetDown
        // rather than a hard per-replica error.
        let err = fleet.preload(&[]).unwrap_err();
        assert!(err.downcast_ref::<FleetDown>().is_some(), "{err:#}");
        assert_eq!(fleet.healthy_replicas(), 0);
        assert_eq!(fleet.metrics().replica_unhealthy.get(), 2);
    }

    #[test]
    fn engine_backed_fleet_summary_and_shutdown() {
        let fleet = FleetHandle::spawn(empty_manifest(), 2).unwrap();
        assert_eq!(fleet.replicas(), 2);
        let s = fleet.summary();
        assert!(s.contains("replicas=2"), "{s}");
        assert!(s.contains("replica 0:") && s.contains("replica 1:"), "{s}");
        assert!(s.contains("compiled"), "{s}");
        assert_eq!(fleet.engine_stats().iter().filter(|e| e.is_some()).count(), 2);
        fleet.shutdown();
        // Replicas floored at 1: a zero-replica config still serves.
        let one = FleetHandle::spawn(empty_manifest(), 0).unwrap();
        assert_eq!(one.replicas(), 1);
        one.shutdown();
    }
}
