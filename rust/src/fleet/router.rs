//! Deterministic least-loaded routing with artifact affinity.
//!
//! Routing is a **pure function** of the replica state snapshot — no RNG,
//! no clock, no round-robin cursor — so the same fleet state always routes
//! the same way (debuggable, and trivially reproducible in tests). The
//! preference order is:
//!
//! 1. healthy replicas only (dead engines are never picked);
//! 2. least in-flight calls (throughput: spread load across streams);
//! 3. among equally-loaded replicas, one that has already been sent the
//!    artifact (affinity: its engine has the compiled executable cached,
//!    so no duplicate compilation);
//! 4. lowest replica index (the deterministic tie-break).
//!
//! Least-loaded deliberately outranks affinity: under load a second
//! replica compiling a duplicate artifact costs one compile, while
//! serializing every bundle of one artifact onto a single replica would
//! forfeit the fleet's whole point. On an idle fleet the affinity bit
//! decides, which is the case that matters for avoiding re-compiles.

/// A replica's routing-relevant state, snapshotted under the fleet's
/// router lock so concurrent dispatches observe each other's in-flight
/// increments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// Replica id (position in the fleet).
    pub index: usize,
    /// False once the replica's engine thread died.
    pub healthy: bool,
    /// Executor calls currently running on the replica.
    pub inflight: i64,
    /// Whether this replica has already been sent the artifact.
    pub has_artifact: bool,
}

/// Pick the replica for a dispatch; `None` when no healthy replica is
/// left (the caller surfaces a typed fleet-down error).
pub fn route(candidates: &[Candidate]) -> Option<usize> {
    candidates
        .iter()
        .filter(|c| c.healthy)
        .min_by_key(|c| (c.inflight, !c.has_artifact, c.index))
        .map(|c| c.index)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(index: usize, healthy: bool, inflight: i64, has_artifact: bool) -> Candidate {
        Candidate { index, healthy, inflight, has_artifact }
    }

    #[test]
    fn empty_or_all_dead_routes_nowhere() {
        assert_eq!(route(&[]), None);
        assert_eq!(route(&[cand(0, false, 0, true), cand(1, false, 0, true)]), None);
    }

    #[test]
    fn least_loaded_wins_over_affinity() {
        // Replica 0 has the artifact but is busy; the idle replica 1 gets
        // the dispatch (throughput beats compile dedup under load).
        let cs = [cand(0, true, 2, true), cand(1, true, 0, false)];
        assert_eq!(route(&cs), Some(1));
    }

    #[test]
    fn affinity_breaks_load_ties() {
        // Equal load: the replica that already compiled the artifact wins
        // even with a higher index.
        let cs = [cand(0, true, 1, false), cand(1, true, 1, true)];
        assert_eq!(route(&cs), Some(1));
    }

    #[test]
    fn index_is_the_final_tie_break() {
        let cs = [cand(0, true, 0, false), cand(1, true, 0, false), cand(2, true, 0, false)];
        assert_eq!(route(&cs), Some(0));
        // ... and it is deterministic: same snapshot, same pick, always.
        for _ in 0..100 {
            assert_eq!(route(&cs), Some(0));
        }
    }

    #[test]
    fn unhealthy_replicas_are_skipped_even_when_idle() {
        let cs = [cand(0, false, 0, true), cand(1, true, 3, false)];
        assert_eq!(route(&cs), Some(1));
    }
}
