//! Minimal leveled logger writing to stderr.
//!
//! Level comes from `WSFM_LOG` (error|warn|info|debug|trace; default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn init_level() -> u8 {
    let lvl = match std::env::var("WSFM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = now.as_secs();
    let ms = now.subsec_millis();
    let _ = writeln!(std::io::stderr(), "[{secs}.{ms:03} {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, "test", format_args!("hello {}", 42));
        crate::info!("macro path {}", 1);
    }
}
