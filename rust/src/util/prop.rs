//! Property-based testing mini-framework (proptest is not vendored).
//!
//! Randomized-input properties with deterministic seeding and linear input
//! shrinking: on failure, the framework retries with "smaller" versions of
//! the failing case (halving sizes / values) and reports the smallest
//! reproduction found. Used across the coordinator invariants (batching,
//! routing, state) per the repo testing strategy.

use crate::core::rng::Pcg64;

/// Number of cases per property (override with WSFM_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("WSFM_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(128)
}

/// A value generator + shrinker.
pub trait Strategy {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate smaller versions of `v` (ordered, most aggressive first).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run a property over `cases` random inputs; panics with the smallest
/// failing input found after shrinking.
pub fn check<S: Strategy, F: Fn(&S::Value) -> Result<(), String>>(name: &str, strat: S, prop: F) {
    let seed = std::env::var("WSFM_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0FFEE_u64);
    let mut rng = Pcg64::new(seed);
    for case in 0..default_cases() {
        let v = strat.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // Shrink loop: greedily walk to smaller failing inputs.
            let mut cur = v;
            let mut cur_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in strat.shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed}):\n  input: {cur:?}\n  error: {cur_msg}",
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Built-in strategies
// ---------------------------------------------------------------------------

/// usize in [lo, hi].
pub struct UsizeRange(pub usize, pub usize);

impl Strategy for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u32) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in [lo, hi).
pub struct F64Range(pub f64, pub f64);

impl Strategy for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        self.0 + rng.uniform() * (self.1 - self.0)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.0 + self.1) / 2.0;
        if (*v - self.0).abs() > 1e-9 {
            vec![self.0, (self.0 + *v) / 2.0, mid.min(*v)]
        } else {
            vec![]
        }
    }
}

/// Vec of values from an element strategy, length in [0, max_len].
pub struct VecOf<S>(pub S, pub usize);

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<S::Value> {
        let len = rng.below(self.1 as u32 + 1) as usize;
        (0..len).map(|_| self.0.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        out.push(Vec::new());
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[..v.len() - 1].to_vec());
        // Shrink one element.
        for (i, elem) in v.iter().enumerate().take(4) {
            for cand in self.0.shrink(elem) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out
    }
}

/// Pair of independent strategies.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("usize in range", UsizeRange(3, 9), |&v| {
            if (3..=9).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_shrunk_input() {
        check("always fails above 0", UsizeRange(0, 100), |&v| {
            if v == 0 {
                Ok(())
            } else {
                Err("nope".into())
            }
        });
    }

    #[test]
    fn shrink_finds_minimal() {
        // Property fails for v >= 10; the shrinker should land near 10.
        let strat = UsizeRange(0, 1000);
        let mut failed_at = None;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("fails >= 10", strat, |&v| if v < 10 { Ok(()) } else { Err(format!("v={v}")) });
        }));
        if let Err(e) = result {
            let msg = e.downcast_ref::<String>().cloned().unwrap_or_default();
            // Extract the shrunk input from the panic message.
            if let Some(pos) = msg.find("input: ") {
                let rest = &msg[pos + 7..];
                let num: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                failed_at = num.parse::<usize>().ok();
            }
        }
        let v = failed_at.expect("property should have failed");
        assert!(v >= 10 && v <= 20, "shrunk to {v}, expected near 10");
    }

    #[test]
    fn vec_strategy_lengths() {
        let strat = VecOf(UsizeRange(0, 5), 8);
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let v = strat.generate(&mut rng);
            assert!(v.len() <= 8);
            assert!(v.iter().all(|&x| x <= 5));
        }
    }

    #[test]
    fn pair_strategy_shrinks_both_sides() {
        let strat = Pair(UsizeRange(0, 10), F64Range(0.0, 1.0));
        let shrunk = strat.shrink(&(5, 0.7));
        assert!(!shrunk.is_empty());
    }
}
