//! Criterion-style micro-benchmark harness (criterion is not vendored).
//!
//! Warm-up, multi-iteration timed samples, mean/median/p95 and a throughput
//! line. Used by the `rust/benches/*.rs` table harnesses and `hotpath.rs`.

use std::time::{Duration, Instant};

/// Result statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<Duration>,
    pub iters_per_sample: u32,
}

impl BenchStats {
    fn per_iter_ns(&self) -> Vec<f64> {
        self.samples
            .iter()
            .map(|d| d.as_nanos() as f64 / self.iters_per_sample as f64)
            .collect()
    }

    pub fn mean_ns(&self) -> f64 {
        let v = self.per_iter_ns();
        v.iter().sum::<f64>() / v.len() as f64
    }

    pub fn median_ns(&self) -> f64 {
        percentile(&mut self.per_iter_ns(), 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        percentile(&mut self.per_iter_ns(), 95.0)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {:>12}  median {:>12}  p95 {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.median_ns()),
            fmt_ns(self.p95_ns()),
            self.samples.len(),
            self.iters_per_sample
        )
    }
}

fn percentile(v: &mut [f64], p: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx]
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// The harness. Runs `f` for `warmup`, then collects `samples` timed samples
/// of `iters` iterations each.
pub struct Bench {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
}

/// `WSFM_BENCH_FAST=1` shrinks every harness to a smoke-test footprint
/// (the CI bench-smoke job): numbers are noisier but the full bench
/// binary finishes in seconds while still exercising every code path and
/// writing `BENCH_hotpath.json`.
fn fast_mode() -> bool {
    std::env::var_os("WSFM_BENCH_FAST").is_some_and(|v| !v.is_empty() && v != "0")
}

impl Default for Bench {
    fn default() -> Self {
        if fast_mode() {
            return Bench {
                warmup: Duration::from_millis(5),
                samples: 3,
                min_sample_time: Duration::from_millis(2),
            };
        }
        Bench {
            warmup: Duration::from_millis(200),
            samples: 12,
            min_sample_time: Duration::from_millis(50),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        if fast_mode() {
            return Bench::default();
        }
        Bench {
            warmup: Duration::from_millis(20),
            samples: 5,
            min_sample_time: Duration::from_millis(10),
        }
    }

    /// Benchmark a closure; `f` is called once per iteration.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // Warm-up and iteration-count calibration.
        let warm_start = Instant::now();
        let mut calls: u64 = 0;
        while warm_start.elapsed() < self.warmup || calls == 0 {
            f();
            calls += 1;
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calls as f64;
        let iters = ((self.min_sample_time.as_secs_f64() / per_call).ceil() as u64).clamp(1, 1 << 24) as u32;

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed());
        }
        let stats = BenchStats { name: name.to_string(), samples, iters_per_sample: iters };
        println!("{}", stats.report());
        stats
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench { warmup: Duration::from_millis(5), samples: 3, min_sample_time: Duration::from_millis(2) };
        let mut acc = 0u64;
        let stats = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(stats.mean_ns() > 0.0);
        assert_eq!(stats.samples.len(), 3);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e10).contains("s"));
    }

    #[test]
    fn percentile_ordering() {
        let s = BenchStats {
            name: "x".into(),
            samples: vec![
                Duration::from_nanos(100),
                Duration::from_nanos(200),
                Duration::from_nanos(900),
            ],
            iters_per_sample: 1,
        };
        assert!(s.median_ns() <= s.p95_ns());
        assert!(s.mean_ns() >= 100.0 && s.mean_ns() <= 900.0);
    }
}
