//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands, with generated `--help` text. Declarative enough for the
//! `wsfm` binary and the bench harnesses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A declarative argument parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct Cli {
    pub name: String,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(name: impl Into<String>, about: &'static str) -> Self {
        Cli { name: name.into(), about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "OPTIONS:");
        for o in &self.opts {
            let kind = if o.is_flag { "".to_string() } else { " <value>".to_string() };
            let def = match o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{}{kind}\n      {}{def}", o.name, o.help);
        }
        s
    }

    /// Parse a token list (without the program/subcommand name).
    pub fn parse(&self, tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        // Fill defaults; check required.
        for o in &self.opts {
            if o.is_flag {
                continue;
            }
            if !args.values.contains_key(o.name) {
                match o.default {
                    Some(d) => {
                        args.values.insert(o.name.to_string(), d.to_string());
                    }
                    None => return Err(format!("missing required option --{}", o.name)),
                }
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be an integer, got {:?}", self.get(name)))
    }
    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be a number, got {:?}", self.get(name)))
    }
    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be an integer, got {:?}", self.get(name)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("test", "a test command")
            .opt("count", "5", "how many")
            .req("name", "who")
            .flag("verbose", "talk more")
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = cli().parse(&toks("--name alice")).unwrap();
        assert_eq!(a.get("name"), "alice");
        assert_eq!(a.get_usize("count").unwrap(), 5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_eq_and_flags_and_positional() {
        let a = cli().parse(&toks("--count=9 --verbose --name=bob extra1 extra2")).unwrap();
        assert_eq!(a.get_usize("count").unwrap(), 9);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&toks("--count 1")).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&toks("--name x --bogus 1")).is_err());
    }

    #[test]
    fn value_missing_errors() {
        assert!(cli().parse(&toks("--name")).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cli().parse(&toks("--name x --verbose=1")).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cli().help_text();
        assert!(h.contains("--count"));
        assert!(h.contains("default: 5"));
    }

    #[test]
    fn numeric_parse_errors() {
        let a = cli().parse(&toks("--name x --count zebra")).unwrap();
        assert!(a.get_usize("count").is_err());
    }
}
