//! In-tree substrates replacing unavailable crates (DESIGN.md §2):
//! JSON codec, CLI parser, micro-benchmark harness, property-testing
//! framework, and a tiny logger.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
