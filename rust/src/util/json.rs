//! Minimal JSON codec (serde/serde_json are not in the vendored crate set).
//!
//! Supports the full JSON data model with a recursive-descent parser and a
//! compact serializer. Used for artifact metadata (`*.meta.json`,
//! `manifest.json`), the wire protocol of the TCP server, and the config
//! system.
//!
//! Two properties matter for the wire layer and are part of this module's
//! contract:
//!
//! - **Objects preserve insertion order.** The serialized key order of
//!   [`Json::obj`] is the construction order, and parsing keeps document
//!   order. The legacy wire format is pinned byte-for-byte by golden tests
//!   in `server::codec`, which requires field order to be stable and
//!   author-controlled rather than alphabetical.
//! - **Unsigned integers are exact.** The parser keeps non-negative integer
//!   literals that fit `u64` as [`Json::U64`], so values above 2^53 (e.g.
//!   RNG seeds near `u64::MAX`) survive a round-trip without drifting
//!   through `f64`. All other numbers are `f64` as before.

use std::fmt;

/// An order-preserving string→[`Json`] map backed by a `Vec`.
///
/// Lookup is linear, which is fine for wire/config objects (tens of keys).
/// `insert` replaces the value of an existing key *in place*, so duplicate
/// JSON keys collapse to the last value without disturbing field order.
#[derive(Debug, Clone, Default)]
pub struct JsonMap {
    entries: Vec<(String, Json)>,
}

impl JsonMap {
    pub fn new() -> JsonMap {
        JsonMap { entries: Vec::new() }
    }

    pub fn insert(&mut self, key: String, value: Json) {
        if let Some(slot) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = value;
        } else {
            self.entries.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.entries.iter().map(|(k, v)| (k, v))
    }
}

impl FromIterator<(String, Json)> for JsonMap {
    fn from_iter<I: IntoIterator<Item = (String, Json)>>(iter: I) -> JsonMap {
        let mut m = JsonMap::new();
        for (k, v) in iter {
            m.insert(k, v);
        }
        m
    }
}

impl<'a> IntoIterator for &'a JsonMap {
    type Item = (&'a String, &'a Json);
    type IntoIter = std::iter::Map<
        std::slice::Iter<'a, (String, Json)>,
        fn(&'a (String, Json)) -> (&'a String, &'a Json),
    >;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter().map(|(k, v)| (k, v))
    }
}

/// Key-set equality (order-insensitive): two maps are equal when they hold
/// the same keys with equal values, regardless of insertion order. Display
/// order is a *serialization* property; equality is semantic.
impl PartialEq for JsonMap {
    fn eq(&self, other: &JsonMap) -> bool {
        self.entries.len() == other.entries.len()
            && self.entries.iter().all(|(k, v)| other.get(k) == Some(v))
    }
}

/// A JSON value.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Non-negative integer kept exact (seeds can exceed 2^53).
    U64(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonMap),
}

/// `U64` and `Num` compare equal when they denote the same number, so
/// callers that construct `Json::Num(42.0)` still match a parsed `42`.
impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::U64(a), Json::U64(b)) => a == b,
            (Json::U64(a), Json::Num(b)) | (Json::Num(b), Json::U64(a)) => *a as f64 == *b,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::U64(u) => Some(*u as f64),
            _ => None,
        }
    }
    /// Exact unsigned integer value. `Num` qualifies only when it is a
    /// non-negative integer below 2^53 (where `f64` is still exact).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::U64(u) => Some(*u),
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::U64(u) => i64::try_from(*u).ok(),
            _ => self.as_f64().map(|n| n as i64),
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::U64(u) => usize::try_from(*u).ok(),
            _ => self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None }),
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&JsonMap> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` for misses (chainable).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn u64(n: u64) -> Json {
        Json::U64(n)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {s})")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = JsonMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str so it's valid).
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    out.push_str(std::str::from_utf8(&s[..len]).map_err(|_| self.err("bad utf8"))?);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short unicode escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        let mut integral = true;
        if self.peek() == Some(b'-') {
            integral = false; // negative values stay f64 (exact to 2^53)
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            integral = false;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            integral = false;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if integral {
            // All-digit unsigned literal: keep exact when it fits u64
            // (seeds near u64::MAX must not round through f64).
            if let Ok(u) = s.parse::<u64>() {
                return Ok(Json::U64(u));
            }
        }
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::U64(u) => write!(f, "{u}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" é 😀");
    }

    #[test]
    fn parse_whitespace_and_empty() {
        assert_eq!(Json::parse(" { } ").unwrap(), Json::Obj(JsonMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":{"c":"d\ne"},"e":[],"f":null,"g":true}"#,
            r#"[0,1e20,"snow☃"]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "roundtrip failed for {c}");
        }
    }

    #[test]
    fn int_display_is_exact() {
        assert_eq!(Json::Num(1024.0).to_string(), "1024");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n":7,"s":"x","b":true,"a":[1]}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(7));
        assert_eq!(v.get("n").as_i64(), Some(7));
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert_eq!(v.get("missing").as_str(), None);
        assert!(v.get("missing").is_null());
        assert_eq!(v.get("a").get("nope"), &Json::Null);
    }

    #[test]
    fn objects_preserve_insertion_order() {
        // Serialization follows construction / document order, not
        // alphabetical order — the wire format depends on this.
        let v = Json::obj(vec![
            ("zeta", Json::num(1.0)),
            ("alpha", Json::num(2.0)),
            ("mid", Json::num(3.0)),
        ]);
        assert_eq!(v.to_string(), r#"{"zeta":1,"alpha":2,"mid":3}"#);
        let p = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(p.to_string(), r#"{"b":1,"a":2}"#);
        // Duplicate keys: last value wins, first position kept.
        let d = Json::parse(r#"{"k":1,"x":2,"k":3}"#).unwrap();
        assert_eq!(d.to_string(), r#"{"k":3,"x":2}"#);
    }

    #[test]
    fn object_equality_is_order_insensitive() {
        let a = Json::parse(r#"{"x":1,"y":2}"#).unwrap();
        let b = Json::parse(r#"{"y":2,"x":1}"#).unwrap();
        assert_eq!(a, b);
        let c = Json::parse(r#"{"x":1,"y":3}"#).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn u64_is_exact_at_max() {
        // u64::MAX = 18446744073709551615 would collapse to 2^64 as f64.
        let s = format!("{{\"seed\":{}}}", u64::MAX);
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("seed").as_u64(), Some(u64::MAX));
        // Round-trip through the serializer keeps every digit.
        assert_eq!(v.to_string(), s);
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(again.get("seed").as_u64(), Some(u64::MAX));
        // 2^53 + 1 is the first integer f64 cannot represent.
        let tricky = Json::parse("9007199254740993").unwrap();
        assert_eq!(tricky.as_u64(), Some(9_007_199_254_740_993));
        assert_eq!(tricky.to_string(), "9007199254740993");
    }

    #[test]
    fn u64_num_cross_equality_and_accessors() {
        assert_eq!(Json::U64(42), Json::Num(42.0));
        assert_eq!(Json::Num(42.0), Json::U64(42));
        assert_ne!(Json::U64(43), Json::Num(42.0));
        assert_eq!(Json::U64(7).as_f64(), Some(7.0));
        assert_eq!(Json::U64(7).as_i64(), Some(7));
        assert_eq!(Json::U64(u64::MAX).as_i64(), None);
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(7.5).as_u64(), None);
        assert_eq!(Json::u64(9).to_string(), "9");
    }
}
