//! Deterministic fault injection for the serving stack.
//!
//! Chaos testing is only useful if a failing run can be replayed: every
//! fault here fires from a **stateless RNG draw**
//! (`Pcg64::substream(fault_seed, call_index, site)`), so the fault
//! schedule — which call at which entry point panics, wedges, or errors —
//! is a pure function of the plan's seed. The same seed replays the same
//! schedule bitwise (pinned by tests); the CI `chaos-smoke` job runs a
//! small seed matrix and logs the seed, so any flaky failure-handling
//! regression arrives with its reproduction recipe attached.
//!
//! [`FaultyExec`] wraps any [`Executor`] (a fleet replica, a mock) and
//! gates each entry point:
//!
//! * **Panic** — the wrapper marks itself dead and returns the typed
//!   [`EngineDead`] from this and every later call. This models the
//!   *observable* of a panicked engine thread: callers of a real
//!   `EngineHandle` whose thread unwound see exactly `EngineDead`
//!   (pinned by the engine tests), so supervising code exercises the
//!   same path without unwinding across the test harness.
//! * **Wedge** — the call stalls for the plan's wedge duration. With a
//!   watchdog armed ([`FaultyExec::with_watchdog`]) and a wedge at or
//!   beyond the deadline, the call sleeps only the deadline and returns
//!   the typed [`EngineTimeout`] — the same observable a watchdog-guarded
//!   `EngineHandle` produces for a wedged engine thread.
//! * **Error** — an ordinary (non-typed) execution failure, the kind a
//!   bad artifact or a transient PJRT error would produce.
//!
//! `meta` is never faulted: it is a pure manifest lookup, identical on
//! every replica, and faulting it would break planning rather than
//! execution — the failure domain this module targets.

use crate::core::rng::Pcg64;
use crate::runtime::{
    ArtifactMeta, EngineDead, EngineTimeout, Executor, LoopReport, LoopScratch, LoopSpec,
};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Fault-injection sites — one per faultable [`Executor`] entry point.
/// The site index is the `row` coordinate of the fault draw's substream,
/// so each entry point sees an independent deterministic schedule.
pub mod site {
    /// `step` / `step_into`.
    pub const STEP: usize = 0;
    /// `draft`.
    pub const DRAFT: usize = 1;
    /// `run_loop` (the REFINE hot path).
    pub const RUN_LOOP: usize = 2;
    /// `probe` (health-loop readmission checks).
    pub const PROBE: usize = 3;
    /// Number of sites (sizes the per-site counters).
    pub const COUNT: usize = 4;
}

/// What a single fault draw decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// No fault: the call proceeds normally.
    None,
    /// Kill the wrapped executor: this call and all later ones return the
    /// typed `EngineDead`.
    Panic,
    /// Stall the call for the plan's wedge duration (or trip the armed
    /// watchdog as a typed `EngineTimeout`).
    Wedge,
    /// Fail the call with an ordinary (non-typed) error.
    Error,
}

/// A deterministic fault schedule: per-call probabilities plus the seed
/// that makes every draw a pure function of `(call_index, site)`.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Substream seed — the whole chaos schedule replays from this.
    pub seed: u64,
    /// Probability a call panics the executor (kills it permanently).
    pub p_panic: f64,
    /// Probability a call wedges for `wedge`.
    pub p_wedge: f64,
    /// Probability a call fails with an ordinary error.
    pub p_error: f64,
    /// Stall length for wedge faults.
    pub wedge: Duration,
}

impl FaultPlan {
    /// A plan that never fires — the passthrough control for
    /// fault-free-path determinism pins.
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan { seed, p_panic: 0.0, p_wedge: 0.0, p_error: 0.0, wedge: Duration::ZERO }
    }

    /// A mixed chaos plan: mostly healthy calls with occasional errors,
    /// short wedges, and rare panics — the profile the chaos integration
    /// test and the CI `chaos-smoke` seeds run under.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            p_panic: 0.02,
            p_wedge: 0.05,
            p_error: 0.10,
            wedge: Duration::from_millis(5),
        }
    }

    /// Decide the fault for call `call_index` at `site` — a pure function
    /// of `(self.seed, call_index, site)`: one uniform draw from the
    /// stateless substream, partitioned panic → wedge → error → none.
    pub fn draw(&self, call_index: u64, site: usize) -> Fault {
        let total = self.p_panic + self.p_wedge + self.p_error;
        if total <= 0.0 {
            return Fault::None;
        }
        let u = Pcg64::substream(self.seed, call_index, site as u64).uniform();
        if u < self.p_panic {
            Fault::Panic
        } else if u < self.p_panic + self.p_wedge {
            Fault::Wedge
        } else if u < total {
            Fault::Error
        } else {
            Fault::None
        }
    }
}

/// An [`Executor`] wrapper that injects the plan's faults at every entry
/// point. Each site keeps its own call counter, so the k-th `run_loop`
/// call always draws the same fault for a given seed regardless of what
/// the other sites did — per-site schedules are independent and exactly
/// replayable. (Under concurrent dispatch the *assignment* of call
/// indices to callers follows arrival order; the schedule itself — which
/// index faults how — is fixed by the seed.)
pub struct FaultyExec {
    inner: Arc<dyn Executor>,
    plan: FaultPlan,
    /// Armed watchdog deadline: wedges at/beyond it become `EngineTimeout`.
    watchdog: Option<Duration>,
    dead: AtomicBool,
    calls: [AtomicU64; site::COUNT],
    fired: [AtomicU64; site::COUNT],
}

impl FaultyExec {
    pub fn new(inner: Arc<dyn Executor>, plan: FaultPlan) -> FaultyExec {
        FaultyExec {
            inner,
            plan,
            watchdog: None,
            dead: AtomicBool::new(false),
            calls: Default::default(),
            fired: Default::default(),
        }
    }

    /// Model a watchdog-guarded engine call: a wedge fault whose stall
    /// reaches `timeout` sleeps only `timeout` and returns the typed
    /// [`EngineTimeout`] instead of completing late.
    pub fn with_watchdog(mut self, timeout: Duration) -> FaultyExec {
        self.watchdog = Some(timeout);
        self
    }

    /// Whether a panic fault has killed this executor.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Calls gated at `site` so far (faulted or not).
    pub fn calls_at(&self, site: usize) -> u64 {
        self.calls[site].load(Ordering::SeqCst)
    }

    /// Faults fired at `site` so far.
    pub fn fired_at(&self, site: usize) -> u64 {
        self.fired[site].load(Ordering::SeqCst)
    }

    /// Total faults fired across all sites.
    pub fn fired_total(&self) -> u64 {
        self.fired.iter().map(|c| c.load(Ordering::SeqCst)).sum()
    }

    /// The per-call fault gate: draw this call's fault and either pass
    /// (Ok) or produce the fault's observable error.
    fn gate(&self, site: usize) -> Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(EngineDead));
        }
        let index = self.calls[site].fetch_add(1, Ordering::SeqCst);
        match self.plan.draw(index, site) {
            Fault::None => Ok(()),
            Fault::Panic => {
                self.fired[site].fetch_add(1, Ordering::SeqCst);
                self.dead.store(true, Ordering::SeqCst);
                Err(anyhow::Error::new(EngineDead))
            }
            Fault::Wedge => {
                self.fired[site].fetch_add(1, Ordering::SeqCst);
                match self.watchdog {
                    Some(timeout) if self.plan.wedge >= timeout => {
                        std::thread::sleep(timeout);
                        Err(anyhow::Error::new(EngineTimeout { timeout }))
                    }
                    _ => {
                        std::thread::sleep(self.plan.wedge);
                        Ok(())
                    }
                }
            }
            Fault::Error => {
                self.fired[site].fetch_add(1, Ordering::SeqCst);
                Err(anyhow!("injected fault: error at site {site} (call {index})"))
            }
        }
    }
}

impl Executor for FaultyExec {
    fn step_into(
        &self,
        artifact: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.gate(site::STEP)?;
        self.inner.step_into(artifact, tokens, t, h, warp, out)
    }

    fn draft(&self, artifact: &str, noise: &[f32]) -> Result<Vec<i32>> {
        self.gate(site::DRAFT)?;
        self.inner.draft(artifact, noise)
    }

    // Pure manifest lookup, deliberately never faulted (module docs).
    fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        self.inner.meta(artifact)
    }

    fn probe(&self) -> Result<()> {
        self.gate(site::PROBE)?;
        self.inner.probe()
    }

    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        scratch: &mut LoopScratch,
    ) -> Result<LoopReport> {
        self.gate(site::RUN_LOOP)?;
        self.inner.run_loop(spec, tokens, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::TestExec;
    use std::time::Instant;

    fn wrapped(plan: FaultPlan) -> FaultyExec {
        let inner: Arc<dyn Executor> = Arc::new(TestExec::drift(vec![1, 4], 2, 4, 1));
        FaultyExec::new(inner, plan)
    }

    #[test]
    fn draw_is_a_pure_function_of_seed_index_site() {
        let plan = FaultPlan::chaos(7);
        // Bitwise replay: the same (index, site) always draws the same
        // fault, across fresh plan values with the same seed.
        let replay = FaultPlan::chaos(7);
        for index in 0..200 {
            for s in 0..site::COUNT {
                assert_eq!(plan.draw(index, s), replay.draw(index, s), "index {index} site {s}");
            }
        }
        // Sites are independent schedules: some index must differ across
        // sites, and distinct seeds must produce distinct schedules.
        assert!(
            (0..200).any(|i| plan.draw(i, site::STEP) != plan.draw(i, site::RUN_LOOP)),
            "per-site schedules should be independent"
        );
        let other = FaultPlan::chaos(8);
        assert!(
            (0..200).any(|i| plan.draw(i, site::RUN_LOOP) != other.draw(i, site::RUN_LOOP)),
            "distinct seeds should produce distinct schedules"
        );
        // A chaos plan actually fires — and fires every kind somewhere.
        for want in [Fault::Panic, Fault::Wedge, Fault::Error, Fault::None] {
            assert!(
                (0..5000).any(|i| plan.draw(i, site::RUN_LOOP) == want),
                "fault kind {want:?} never drawn in 5000 calls"
            );
        }
    }

    #[test]
    fn fault_free_plan_is_a_passthrough() {
        let exec = wrapped(FaultPlan::none(7));
        let meta = exec.meta("mock_cold_step_b4").unwrap();
        assert_eq!(meta.batch, 4);
        exec.probe().unwrap();
        let spec = LoopSpec::full("mock_cold_step_b4".into(), 10, 0.5, 1.0, 7, false);
        let mut tokens = vec![0i32; 4 * 2];
        let mut scratch = LoopScratch::default();
        let report = exec.run_loop(&spec, &mut tokens, &mut scratch).unwrap();
        assert_eq!(report.nfe, 5);
        assert_eq!(exec.fired_total(), 0);
        assert!(!exec.is_dead());
        assert_eq!(exec.calls_at(site::RUN_LOOP), 1);
    }

    #[test]
    fn panic_fault_kills_the_executor_permanently() {
        // p_panic = 1: the first gated call dies, and every later call —
        // at any site — returns the typed EngineDead without reaching the
        // inner executor.
        let plan = FaultPlan {
            seed: 3,
            p_panic: 1.0,
            p_wedge: 0.0,
            p_error: 0.0,
            wedge: Duration::ZERO,
        };
        let exec = wrapped(plan);
        let err = exec.probe().unwrap_err();
        assert!(err.downcast_ref::<EngineDead>().is_some(), "{err:#}");
        assert!(exec.is_dead());
        let err = exec.draft("mock_cold_step_b4", &[0.0]).unwrap_err();
        assert!(err.downcast_ref::<EngineDead>().is_some(), "{err:#}");
        // Dead calls are not drawn: only the killing call counted.
        assert_eq!(exec.calls_at(site::PROBE), 1);
        assert_eq!(exec.calls_at(site::DRAFT), 0);
        // meta stays un-faulted even on a dead wrapper (pure lookup).
        assert!(exec.meta("mock_cold_step_b4").is_ok());
    }

    #[test]
    fn error_fault_is_ordinary_not_typed() {
        let plan =
            FaultPlan { seed: 3, p_panic: 0.0, p_wedge: 0.0, p_error: 1.0, wedge: Duration::ZERO };
        let exec = wrapped(plan);
        let err = exec.probe().unwrap_err();
        assert!(err.downcast_ref::<EngineDead>().is_none(), "{err:#}");
        assert!(err.downcast_ref::<EngineTimeout>().is_none(), "{err:#}");
        assert!(err.to_string().contains("injected fault"), "{err:#}");
        assert!(!exec.is_dead());
        // The next call draws independently; the wrapper survives errors.
        assert!(exec.probe().is_err());
        assert_eq!(exec.calls_at(site::PROBE), 2);
    }

    #[test]
    fn wedge_with_armed_watchdog_trips_typed_timeout() {
        let plan = FaultPlan {
            seed: 3,
            p_panic: 0.0,
            p_wedge: 1.0,
            p_error: 0.0,
            wedge: Duration::from_millis(200),
        };
        let exec = wrapped(plan).with_watchdog(Duration::from_millis(10));
        let start = Instant::now();
        let err = exec.probe().unwrap_err();
        let t = err
            .downcast_ref::<EngineTimeout>()
            .unwrap_or_else(|| panic!("expected EngineTimeout, got {err:#}"));
        assert_eq!(t.timeout, Duration::from_millis(10));
        assert!(
            start.elapsed() < Duration::from_millis(200),
            "watchdog should cut the wedge short"
        );
        assert!(!exec.is_dead(), "a timeout is not a death — the supervisor decides");
    }

    #[test]
    fn short_wedge_under_watchdog_completes_normally() {
        let plan = FaultPlan {
            seed: 3,
            p_panic: 0.0,
            p_wedge: 1.0,
            p_error: 0.0,
            wedge: Duration::from_millis(1),
        };
        let exec = wrapped(plan).with_watchdog(Duration::from_millis(500));
        exec.probe().unwrap();
        assert_eq!(exec.fired_at(site::PROBE), 1, "the wedge did fire, just sub-deadline");
    }
}
