//! Draft models: the "computationally lightweight generative models" of the
//! paper (§3) that supply warm-start initial samples at `t0`.
//!
//! * [`HloDraft`] — LSTM / PCA samplers exported as HLO artifacts; the
//!   coordinator feeds them Gumbel / Gaussian noise (Rust owns RNG).
//! * [`MixtureDraft`] — the two-moons contrived drafts (good/fair/poor),
//!   computed directly in Rust (paper Fig. 4 c-e).
//! * [`NoiseDraft`] — pure uniform noise (what cold DFM starts from);
//!   exists so every sampler run can be expressed as "draft + refine".

use crate::core::rng::Pcg64;
use crate::core::tensor::TokenBatch;
use crate::data::two_moons::{self, DraftKind};
use crate::runtime::engine::Executor;
use anyhow::{bail, Result};

/// A draft model produces a `[B, N]` batch of initial token sequences.
pub trait Draft: Send + Sync {
    /// Human-readable kind ("lstm", "pca", "good", "noise", ...).
    fn kind(&self) -> &str;
    /// Generate `batch` sequences of `seq_len` tokens.
    fn generate(&self, batch: usize, seq_len: usize, rng: &mut Pcg64) -> Result<TokenBatch>;
}

/// Uniform-noise draft over a vocabulary.
pub struct NoiseDraft {
    pub vocab: usize,
}

impl Draft for NoiseDraft {
    fn kind(&self) -> &str {
        "noise"
    }

    fn generate(&self, batch: usize, seq_len: usize, rng: &mut Pcg64) -> Result<TokenBatch> {
        let mut tb = TokenBatch::zeros(batch, seq_len);
        for t in tb.tokens.iter_mut() {
            *t = rng.below(self.vocab as u32) as i32;
        }
        Ok(tb)
    }
}

/// Two-moons contrived draft models (paper Fig. 4 c-e).
pub struct MixtureDraft {
    pub draft_kind: DraftKind,
}

impl Draft for MixtureDraft {
    fn kind(&self) -> &str {
        self.draft_kind.name()
    }

    fn generate(&self, batch: usize, seq_len: usize, rng: &mut Pcg64) -> Result<TokenBatch> {
        if seq_len != two_moons::N_TOKENS {
            bail!("two-moons drafts have seq_len 2, asked for {seq_len}");
        }
        let mut tb = TokenBatch::zeros(batch, seq_len);
        for i in 0..batch {
            let p = two_moons::draft_sample(self.draft_kind, rng);
            tb.row_mut(i).copy_from_slice(&p);
        }
        Ok(tb)
    }
}

/// Noise kind an HLO draft artifact expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftNoise {
    /// Gumbel(0,1) per (position, vocab) — LSTM Gumbel-max sampling.
    Gumbel,
    /// Standard normal latents — PCA-Gaussian sampler.
    Gaussian,
}

/// A draft model backed by an AOT HLO artifact (LSTM or PCA).
pub struct HloDraft<'a> {
    pub exec: &'a dyn Executor,
    /// Artifact name (fixed batch shape, e.g. `text8_draft_lstm_b32`).
    pub artifact: String,
    pub noise: DraftNoise,
    kind_name: String,
}

impl<'a> HloDraft<'a> {
    pub fn new(exec: &'a dyn Executor, artifact: impl Into<String>, noise: DraftNoise) -> Self {
        let artifact = artifact.into();
        let kind_name = match noise {
            DraftNoise::Gumbel => "lstm".to_string(),
            DraftNoise::Gaussian => "pca".to_string(),
        };
        HloDraft { exec, artifact, noise, kind_name }
    }
}

impl<'a> Draft for HloDraft<'a> {
    fn kind(&self) -> &str {
        &self.kind_name
    }

    fn generate(&self, batch: usize, seq_len: usize, rng: &mut Pcg64) -> Result<TokenBatch> {
        let meta = self.exec.meta(&self.artifact)?;
        if meta.batch != batch || meta.seq_len != seq_len {
            bail!(
                "draft artifact {} is [{}, {}], asked for [{}, {}]",
                self.artifact,
                meta.batch,
                meta.seq_len,
                batch,
                seq_len
            );
        }
        let in_spec = meta.inputs.first().ok_or_else(|| anyhow::anyhow!("draft missing input"))?;
        let mut noise = vec![0.0f32; in_spec.numel()];
        match self.noise {
            DraftNoise::Gumbel => rng.fill_gumbel_f32(&mut noise),
            DraftNoise::Gaussian => rng.fill_normal_f32(&mut noise),
        }
        let tokens = self.exec.draft(&self.artifact, &noise)?;
        Ok(TokenBatch { batch, seq_len, tokens })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_draft_in_vocab() {
        let d = NoiseDraft { vocab: 7 };
        let mut rng = Pcg64::new(0);
        let tb = d.generate(10, 5, &mut rng).unwrap();
        assert_eq!((tb.batch, tb.seq_len), (10, 5));
        assert!(tb.tokens.iter().all(|&t| (0..7).contains(&t)));
        assert_eq!(d.kind(), "noise");
    }

    #[test]
    fn mixture_draft_shapes() {
        let d = MixtureDraft { draft_kind: DraftKind::Fair };
        let mut rng = Pcg64::new(1);
        let tb = d.generate(32, 2, &mut rng).unwrap();
        assert_eq!(tb.batch, 32);
        assert!(tb.tokens.iter().all(|&t| (0..128).contains(&t)));
        assert_eq!(d.kind(), "fair");
        // Wrong seq_len rejected.
        assert!(d.generate(4, 3, &mut rng).is_err());
    }

    #[test]
    fn noise_draft_distribution_uniform() {
        let d = NoiseDraft { vocab: 4 };
        let mut rng = Pcg64::new(2);
        let tb = d.generate(100, 100, &mut rng).unwrap();
        let mut counts = [0usize; 4];
        for &t in &tb.tokens {
            counts[t as usize] += 1;
        }
        for c in counts {
            let f = c as f64 / 10_000.0;
            assert!((f - 0.25).abs() < 0.03, "{f}");
        }
    }
}
