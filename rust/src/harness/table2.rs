//! Paper Table 2 + Fig 10: synth-text8 NLL / entropy / generation time.
//!
//! Systems: LSTM draft only, cold DFM, WS-DFM (t0=0.8, t0=0.5), and the
//! oracle refiner (the Gemma3-27B substitute — DESIGN.md §2). The evaluator
//! is a Kneser-Ney char 5-gram trained on the *held-out* corpus (the
//! GPT-J-6B substitute).

use crate::coordinator::request::DraftSpec;
use crate::core::rng::Pcg64;
use crate::core::schedule::WarpMode;
use crate::data::tokenizer::{CharTokenizer, TEXT8_VOCAB};
use crate::eval::ngram::NgramLM;
use crate::harness::common::{self, Env};
use crate::util::cli::Cli;
use anyhow::{Context, Result};
use std::time::Duration;

/// Paper Table 2 reference: (system, NLL, entropy, seconds/sentence).
pub const PAPER: &[(&str, f64, f64, f64)] = &[
    ("LSTM", 6.87, 7.19, 0.0),
    ("Original DFM", 6.58, 7.14, 6.56),
    ("WS-DFM t0=0.8", 6.54, 7.11, 1.36),
    ("WS-DFM t0=0.5", 6.48, 7.05, 3.36),
    ("Refined (oracle)", 6.54, 7.18, 0.0),
];

#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub nll: f64,
    pub entropy_bits: f64,
    pub nfe: usize,
    pub secs_per_sentence: f64,
}

pub struct TextBenchCfg {
    pub domain: &'static str,
    pub eval_file: &'static str,
    pub eval_order: usize,
    pub refine_order: usize,
    pub vocab: usize,
    pub steps_cold: usize,
    pub n_eval: usize,
    pub seed: u64,
}

/// Shared text-domain harness (tables 2 and 3 differ only in config).
pub fn run_text(env: &Env, cfg: &TextBenchCfg, eval_stream: &[i32], train_stream: &[i32]) -> Result<Vec<Row>> {
    let lm = NgramLM::fit(eval_stream, cfg.eval_order, cfg.vocab);
    let mut rows = Vec::new();
    let mut eval_rows = |label: &str, samples: &[Vec<i32>], nfe: usize, total: Duration| {
        let m = lm.evaluate(samples);
        rows.push(Row {
            label: label.to_string(),
            nll: m.nll,
            entropy_bits: m.entropy_bits,
            nfe,
            secs_per_sentence: total.as_secs_f64() / samples.len().max(1) as f64,
        });
    };

    // LSTM draft only.
    let (drafts, draft_time) = env.run_draft_only(cfg.domain, DraftSpec::Lstm, cfg.n_eval, cfg.seed)?;
    eval_rows("LSTM (draft only)", &drafts, 0, draft_time);

    // Cold DFM.
    let (cold, nfe, t) = env.run_system(
        cfg.domain,
        "cold",
        DraftSpec::Noise,
        0.0,
        cfg.steps_cold,
        WarpMode::Exact,
        cfg.n_eval,
        cfg.seed + 1,
    )?;
    eval_rows("Original DFM", &cold, nfe, t);

    // WS-DFM at the paper's two warm starts.
    for t0 in [0.8, 0.5] {
        let tag = common::ws_tag(t0);
        let (samples, nfe, t) = env.run_system(
            cfg.domain,
            &tag,
            DraftSpec::Lstm,
            t0,
            cfg.steps_cold,
            WarpMode::Literal,
            cfg.n_eval,
            cfg.seed + 2,
        )?;
        eval_rows(&format!("WS-DFM t0={t0}"), &samples, nfe, t);
    }

    // Oracle-refined drafts (the LLM-refinement substitute).
    let refine_lm = NgramLM::fit(train_stream, cfg.refine_order, cfg.vocab);
    let mut rng = Pcg64::new(cfg.seed + 3);
    let refined: Vec<Vec<i32>> =
        drafts.iter().map(|d| common::oracle_refine(d, &refine_lm, &mut rng, 0.35)).collect();
    eval_rows("Refined (oracle)", &refined, 0, Duration::ZERO);

    // WS-DFM under the scored controller (§Control), appended after the
    // paper rows so the paper-reference columns stay aligned: same
    // ws_t050 artifact, but the per-bundle t0 comes from the LSTM draft
    // batch's proxy score. t0_min = 0.5 keeps every evaluation time
    // inside the artifact's trained range and caps the NFE at the
    // static-t0=0.5 budget (the guarantee floor, asserted here).
    {
        use crate::config::ControlConfig;
        use crate::control::Controller;
        use crate::core::schedule::guaranteed_nfe;
        let ctl_cfg = ControlConfig {
            mode: "scored".into(),
            t0_min: 0.5,
            ..ControlConfig::default()
        };
        let controller = Controller::from_config(&ctl_cfg)?;
        let (samples, nfe, t0_used, t) = env.run_system_with_controller(
            cfg.domain,
            &common::ws_tag(0.5),
            DraftSpec::Lstm,
            0.5,
            cfg.steps_cold,
            WarpMode::Literal,
            cfg.n_eval,
            cfg.seed + 2,
            controller,
        )?;
        let budget = guaranteed_nfe(cfg.steps_cold, 0.5);
        assert!(nfe <= budget, "scored: NFE {nfe} exceeds floor budget {budget}");
        eval_rows(&format!("WS-DFM scored (t0={t0_used:.2})"), &samples, nfe, t);
    }

    // WS-DFM under the gated cascade (§Cascade): the same ws_t050 static
    // run split into ladder segments, with a quality gate between them —
    // a bundle whose intermediate state already scores well exits early,
    // so the reported NFE can only be <= the static row's. The guarantee
    // is asserted: summed per-stage NFE never exceeds the unsplit budget.
    {
        use crate::cascade::Cascade;
        use crate::config::CascadeConfig;
        use crate::control::Controller;
        use crate::core::schedule::guaranteed_nfe;
        let cascade = Cascade::from_config(&CascadeConfig {
            mode: "gated".into(),
            ..CascadeConfig::default()
        })?;
        let (samples, nfe, _t0_used, info, t) = env.run_system_cascade(
            cfg.domain,
            &common::ws_tag(0.5),
            DraftSpec::Lstm,
            0.5,
            cfg.steps_cold,
            WarpMode::Literal,
            cfg.n_eval,
            cfg.seed + 2,
            Controller::static_default(),
            cascade,
        )?;
        let budget = guaranteed_nfe(cfg.steps_cold, 0.5);
        assert!(nfe <= budget, "cascade: NFE {nfe} exceeds unsplit budget {budget}");
        let (stages, exited) = info
            .as_ref()
            .map(|i| (i.stages_used, i.early_exit))
            .unwrap_or((1, false));
        if let Some(i) = &info {
            assert_eq!(i.nfe_per_stage.iter().sum::<usize>(), nfe, "stage NFEs must tile");
        }
        eval_rows(
            &format!("WS-DFM cascade gated ({stages} stage{}{})",
                if stages == 1 { "" } else { "s" },
                if exited { ", early exit" } else { "" }),
            &samples,
            nfe,
            t,
        );
    }

    Ok(rows)
}

pub fn print(title: &str, rows: &[Row], paper: &[(&str, f64, f64, f64)], ppl: bool) {
    let metric = if ppl { "ppl" } else { "NLL" };
    common::print_table_header(
        title,
        &[metric, "entropy", "NFE", "s/sentence", &format!("paper {metric}"), "paper s"],
    );
    for (i, r) in rows.iter().enumerate() {
        let (p_m, p_t) = paper.get(i).map(|p| (p.1, p.3)).unwrap_or((f64::NAN, f64::NAN));
        let m = if ppl { r.nll.exp() } else { r.nll };
        common::print_row(
            &r.label,
            &[
                format!("{m:.3}"),
                format!("{:.3}", r.entropy_bits),
                format!("{}", r.nfe),
                format!("{:.3}", r.secs_per_sentence),
                format!("{p_m:.2}"),
                format!("{p_t:.2}"),
            ],
        );
    }
}

/// Dump Fig 10/14-style sample texts for any text domain.
pub fn dump_samples_generic(
    env: &Env,
    out_dir: &std::path::Path,
    domain: &str,
    prefix: &str,
    steps_cold: usize,
    seed: u64,
    decode: &dyn Fn(&[i32]) -> String,
) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let systems: [(&str, &str, f64, WarpMode); 4] = [
        ("dfm", "cold", 0.0, WarpMode::Exact),
        ("ws_t080", "ws_t080", 0.8, WarpMode::Literal),
        ("ws_t050", "ws_t050", 0.5, WarpMode::Literal),
        ("lstm", "", 0.0, WarpMode::Exact),
    ];
    for (name, tag, t0, warp) in systems {
        let samples = if tag.is_empty() {
            env.run_draft_only(domain, DraftSpec::Lstm, 3, seed)?.0
        } else {
            let draft = if tag == "cold" { DraftSpec::Noise } else { DraftSpec::Lstm };
            env.run_system(domain, tag, draft, t0, steps_cold, warp, 3, seed)?.0
        };
        let text: Vec<String> = samples
            .iter()
            .enumerate()
            .map(|(i, s)| format!("(Sample {})\n{}", i + 1, decode(s)))
            .collect();
        std::fs::write(out_dir.join(format!("{prefix}_{name}.txt")), text.join("\n\n"))?;
    }
    println!("sample texts written to {out_dir:?}");
    Ok(())
}

/// Dump Fig 10 sample texts (text8).
pub fn dump_samples(env: &Env, out_dir: &std::path::Path, steps_cold: usize, seed: u64) -> Result<()> {
    let tok = CharTokenizer;
    dump_samples_generic(env, out_dir, "text8", "fig10", steps_cold, seed, &|s| tok.decode(s))
}

/// CLI entry (`wsfm bench-table2`).
pub fn main(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm bench-table2", "text8 NLL/entropy/time (paper Table 2)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("n", "48", "sentences per system")
        .opt("steps", "256", "cold-run step count (paper: 1024)")
        .opt("seed", "0", "rng seed")
        .opt("out", "out", "sample output directory")
        .flag("dump-samples", "also dump Fig 10 sample texts");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let env = Env::load(args.get("artifacts"))?;

    let eval_path = env.manifest.dir.join("text8_eval.txt");
    let eval_stream = crate::data::corpus::load_text8(&eval_path)
        .with_context(|| format!("loading {eval_path:?}"))?;
    let train_stream = crate::data::corpus::load_text8(&env.manifest.dir.join("text8_corpus.txt"))?;

    let steps = args.get_usize("steps").map_err(|m| anyhow::anyhow!(m))?;
    let cfg = TextBenchCfg {
        domain: "text8",
        eval_file: "text8_eval.txt",
        eval_order: 5,
        refine_order: 4,
        vocab: TEXT8_VOCAB,
        steps_cold: steps,
        n_eval: args.get_usize("n").map_err(|m| anyhow::anyhow!(m))?,
        seed: args.get_u64("seed").map_err(|m| anyhow::anyhow!(m))?,
    };
    let rows = run_text(&env, &cfg, &eval_stream, &train_stream[..train_stream.len().min(200_000)])?;
    print("Table 2 (synth-text8)", &rows, PAPER, false);
    println!(
        "\nnote: steps_cold={} here (paper: 1024); NFE ratios and the paper's\nordering are the comparison target, not absolute values (DESIGN.md §2).",
        steps
    );
    if args.flag("dump-samples") {
        dump_samples(&env, std::path::Path::new(args.get("out")), steps, 7)?;
    }
    env.engine.shutdown();
    Ok(())
}
