//! Paper Table 4 + Figs 6-9/12-13: image FID / generation time.
//!
//! Systems per color mode: PCA draft only (the DC-GAN substitute), cold
//! DFM, WS-DFM at t0 ∈ {0.8, 0.65, 0.5}. FID is the Fréchet distance over
//! the fixed random-conv features (DESIGN.md §2), referenced against the
//! training set the models were fitted on.

use crate::coordinator::request::DraftSpec;
use crate::core::schedule::WarpMode;
use crate::data::corpus::{load_u8_matrix};
use crate::data::shapes;
use crate::eval::fid::{fid_images, FeatureExtractor};
use crate::harness::common::{self, Env};
use crate::util::cli::Cli;
use anyhow::{Context, Result};

/// Paper Table 4 reference: (system, gray FID, gray s, color FID, color s).
pub const PAPER: &[(&str, f64, f64, f64, f64)] = &[
    ("DC-GAN (draft)", 74.64, 0.0, 80.91, 0.0),
    ("Original DFM", 30.46, 0.62, 36.91, 2.64),
    ("WS-DFM t0=0.8", 23.59, 0.13, 37.02, 0.55),
    ("WS-DFM t0=0.65", 22.75, 0.23, 36.47, 0.94),
    ("WS-DFM t0=0.5", 19.47, 0.32, 34.65, 1.34),
];

#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub fid: f64,
    pub nfe: usize,
    pub secs_per_image: f64,
}

pub struct ImageCfg {
    pub domain: &'static str,
    pub side: usize,
    pub channels: usize,
    pub steps_cold: usize,
    pub n_eval: usize,
    pub seed: u64,
}

pub fn run_images(env: &Env, cfg: &ImageCfg) -> Result<Vec<Row>> {
    let n_tokens = cfg.side * cfg.side * cfg.channels;
    let train_path = env.manifest.dir.join(format!("{}_train.bin", cfg.domain));
    let train = load_u8_matrix(&train_path, n_tokens)
        .with_context(|| format!("loading {train_path:?}"))?;
    let reference: Vec<Vec<i32>> = train.into_iter().take(2048).collect();
    let extractor = FeatureExtractor::new(cfg.side, cfg.channels, 8, 0xF1D);

    let mut rows = Vec::new();

    // PCA draft only.
    let (drafts, t) = env.run_draft_only(cfg.domain, DraftSpec::Pca, cfg.n_eval, cfg.seed)?;
    rows.push(Row {
        label: "PCA draft (DC-GAN sub)".into(),
        fid: fid_images(&extractor, &reference, &drafts),
        nfe: 0,
        secs_per_image: t.as_secs_f64() / cfg.n_eval as f64,
    });

    // Cold DFM.
    let (cold, nfe, t) = env.run_system(
        cfg.domain,
        "cold",
        DraftSpec::Noise,
        0.0,
        cfg.steps_cold,
        WarpMode::Exact,
        cfg.n_eval,
        cfg.seed + 1,
    )?;
    rows.push(Row {
        label: "Original DFM".into(),
        fid: fid_images(&extractor, &reference, &cold),
        nfe,
        secs_per_image: t.as_secs_f64() / cfg.n_eval as f64,
    });

    for t0 in [0.8, 0.65, 0.5] {
        let tag = common::ws_tag(t0);
        let (samples, nfe, t) = env.run_system(
            cfg.domain,
            &tag,
            DraftSpec::Pca,
            t0,
            cfg.steps_cold,
            WarpMode::Literal,
            cfg.n_eval,
            cfg.seed + 2,
        )?;
        rows.push(Row {
            label: format!("WS-DFM t0={t0}"),
            fid: fid_images(&extractor, &reference, &samples),
            nfe,
            secs_per_image: t.as_secs_f64() / cfg.n_eval as f64,
        });
    }
    Ok(rows)
}

pub fn print(title: &str, rows: &[Row], paper_col: usize) {
    common::print_table_header(title, &["FID*", "NFE", "s/image", "paper FID", "paper s"]);
    for (i, r) in rows.iter().enumerate() {
        let (p_fid, p_s) = PAPER
            .get(i)
            .map(|p| if paper_col == 0 { (p.1, p.2) } else { (p.3, p.4) })
            .unwrap_or((f64::NAN, f64::NAN));
        common::print_row(
            &r.label,
            &[
                format!("{:.2}", r.fid),
                format!("{}", r.nfe),
                format!("{:.3}", r.secs_per_image),
                format!("{p_fid:.2}"),
                format!("{p_s:.2}"),
            ],
        );
    }
}

/// Dump Fig 6/8 sample grids (PGM/PPM) and Fig 7/9 progress strips.
pub fn dump_figures(env: &Env, out_dir: &std::path::Path, cfg: &ImageCfg) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let gray = cfg.channels == 1;
    let fig_grid = if gray { "fig6" } else { "fig8" };
    let fig_strip = if gray { "fig7" } else { "fig9" };
    let write = |path: &std::path::Path, tokens: &[i32]| -> Result<()> {
        if gray {
            shapes::write_pgm(path, tokens, cfg.side)?;
        } else {
            shapes::write_ppm(path, tokens, cfg.side)?;
        }
        Ok(())
    };

    // Fig 6/8: grids for each system (4 images each).
    let systems: [(&str, &str, DraftSpec, f64); 3] = [
        ("dfm", "cold", DraftSpec::Noise, 0.0),
        ("ws_t080", "ws_t080", DraftSpec::Pca, 0.8),
        ("ws_t050", "ws_t050", DraftSpec::Pca, 0.5),
    ];
    for (name, tag, draft, t0) in systems {
        let warp = if tag == "cold" { WarpMode::Exact } else { WarpMode::Literal };
        let (samples, _, _) =
            env.run_system(cfg.domain, tag, draft, t0, cfg.steps_cold, warp, 4, 11)?;
        for (i, s) in samples.iter().enumerate() {
            let ext = if gray { "pgm" } else { "ppm" };
            write(&out_dir.join(format!("{fig_grid}_{name}_{i}.{ext}")), s)?;
        }
    }
    // Draft-only panel.
    let (drafts, _) = env.run_draft_only(cfg.domain, DraftSpec::Pca, 4, 11)?;
    for (i, s) in drafts.iter().enumerate() {
        let ext = if gray { "pgm" } else { "ppm" };
        write(&out_dir.join(format!("{fig_grid}_draft_{i}.{ext}")), s)?;
    }

    // Fig 7/9: refinement progress strips (t0=0.5, a few snapshots).
    let tag = common::ws_tag(0.5);
    let batches = env.manifest.step_batches(cfg.domain, &tag);
    let b = *batches.first().context("no ws_t050 artifacts")?;
    let meta = env.manifest.find_step(cfg.domain, &tag, b)?;
    let mut rng = crate::core::rng::Pcg64::new(13);
    let draft_meta = env.manifest.find_draft(cfg.domain, "pca", b)?;
    let d = crate::draft::HloDraft::new(
        &env.engine as &dyn crate::runtime::Executor,
        draft_meta.name.clone(),
        crate::draft::DraftNoise::Gaussian,
    );
    let init = crate::draft::Draft::generate(&d, b, meta.seq_len, &mut rng)?;
    let params = crate::sampler::SamplerParams {
        artifact: meta.name.clone(),
        steps_cold: cfg.steps_cold,
        t0: 0.5,
        warp_mode: WarpMode::Literal,
    };
    let out = crate::sampler::dfm::sample_warm(&env.engine, &params, init, &mut rng, true)?;
    let trace = out.trace.unwrap();
    for row in 0..b.min(4) {
        for (j, (_, tokens)) in trace.row_snapshots(row, 6).iter().enumerate() {
            let ext = if gray { "pgm" } else { "ppm" };
            write(&out_dir.join(format!("{fig_strip}_row{row}_step{j}.{ext}")), tokens)?;
        }
    }
    println!("image figures written to {out_dir:?}");
    Ok(())
}

/// CLI entry (`wsfm bench-table4`).
pub fn main(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm bench-table4", "image FID/time (paper Table 4)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("n", "128", "images per system")
        .opt("steps", "64", "cold-run step count (paper: 1024)")
        .opt("seed", "0", "rng seed")
        .opt("mode", "both", "gray|color|both")
        .opt("out", "out", "figure output directory")
        .flag("dump-figures", "also dump Figs 6-9");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let env = Env::load(args.get("artifacts"))?;
    let n = args.get_usize("n").map_err(|m| anyhow::anyhow!(m))?;
    let steps = args.get_usize("steps").map_err(|m| anyhow::anyhow!(m))?;
    let seed = args.get_u64("seed").map_err(|m| anyhow::anyhow!(m))?;
    let mode = args.get("mode").to_string();

    if mode == "gray" || mode == "both" {
        let cfg = ImageCfg {
            domain: "img_gray",
            side: shapes::GRAY_SIDE,
            channels: 1,
            steps_cold: steps,
            n_eval: n,
            seed,
        };
        let rows = run_images(&env, &cfg)?;
        print("Table 4 (synth-shapes, gray)", &rows, 0);
        if args.flag("dump-figures") {
            dump_figures(&env, std::path::Path::new(args.get("out")), &cfg)?;
        }
    }
    if mode == "color" || mode == "both" {
        let cfg = ImageCfg {
            domain: "img_color",
            side: shapes::COLOR_SIDE,
            channels: 3,
            steps_cold: steps,
            n_eval: n,
            seed,
        };
        let rows = run_images(&env, &cfg)?;
        print("Table 4 (synth-shapes, color)", &rows, 1);
        if args.flag("dump-figures") {
            dump_figures(&env, std::path::Path::new(args.get("out")), &cfg)?;
        }
    }
    println!("\n* FID here is Fréchet over fixed random-conv features (DESIGN.md §2);\ncompare orderings and the WS-vs-cold gap, not absolute values.");
    env.engine.shutdown();
    Ok(())
}
