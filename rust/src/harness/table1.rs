//! Paper Table 1 + Figs 4/5: two-moons SKL vs NFE.
//!
//! Rows: original (cold) DFM at 20 steps, then WS-DFM for the three
//! contrived draft models at the paper's t0 grid. For each WS row we print
//! the measured SKL, the guaranteed NFE, and whether quality is no worse
//! than cold DFM's (the paper's ✓/✗ marks). Paper reference values are
//! shown in the last column.

use crate::coordinator::request::DraftSpec;
use crate::core::rng::Pcg64;
use crate::core::schedule::{guaranteed_nfe, WarpMode};
use crate::data::two_moons::{self, DraftKind};
use crate::eval::skl::skl_points;
use crate::harness::common::{self, Env};
use crate::sampler::dfm::{sample_warm, SamplerParams};
use crate::util::cli::Cli;
use anyhow::Result;
use std::io::Write;

/// Paper Table 1 reference rows: (draft, t0, paper SKL, paper NFE).
pub const PAPER_ROWS: &[(&str, f64, f64, usize)] = &[
    ("good", 0.95, 0.74, 1),
    ("good", 0.9, 0.54, 2),
    ("good", 0.8, 0.37, 4),
    ("fair", 0.8, 0.86, 4),
    ("fair", 0.5, 0.51, 10),
    ("poor", 0.8, 1.35, 4),
    ("poor", 0.5, 0.64, 10),
    ("poor", 0.35, 0.54, 13),
];
pub const PAPER_COLD_SKL: f64 = 0.62;
pub const STEPS_COLD: usize = 20;

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub skl: f64,
    pub nfe: usize,
    pub secs_per_sample: f64,
    pub ok: Option<bool>,
}

/// Run the full table; returns rows (cold first).
pub fn run(env: &Env, n_eval: usize, seed: u64) -> Result<Vec<Row>> {
    run_with_warp(env, n_eval, seed, WarpMode::Literal)
}

/// Run with an explicit update-rule variant (the DESIGN.md ablation).
pub fn run_with_warp(env: &Env, n_eval: usize, seed: u64, warp: WarpMode) -> Result<Vec<Row>> {
    let mut rng = Pcg64::new(seed ^ 0x7a0);
    let target = two_moons::sample_batch(n_eval, &mut rng);
    let mut rows = Vec::new();

    // Cold DFM baseline.
    let (samples, nfe, elapsed) = env.run_system(
        "two_moons",
        "cold",
        DraftSpec::Noise,
        0.0,
        STEPS_COLD,
        WarpMode::Exact,
        n_eval,
        seed,
    )?;
    let pts: Vec<[i32; 2]> = samples.iter().map(|s| [s[0], s[1]]).collect();
    let cold_skl = skl_points(&target, &pts);
    rows.push(Row {
        label: "Original DFM (t0=0)".into(),
        skl: cold_skl,
        nfe,
        secs_per_sample: elapsed.as_secs_f64() / n_eval as f64,
        ok: None,
    });

    for &(kind, t0, _, _) in PAPER_ROWS {
        let tag = common::ws_tag_draft(kind, t0);
        let draft = DraftSpec::Mixture(DraftKind::parse(kind).unwrap());
        let (samples, nfe, elapsed) = env.run_system(
            "two_moons",
            &tag,
            draft,
            t0,
            STEPS_COLD,
            warp,
            n_eval,
            seed + 1,
        )?;
        let pts: Vec<[i32; 2]> = samples.iter().map(|s| [s[0], s[1]]).collect();
        let skl = skl_points(&target, &pts);
        assert_eq!(nfe, guaranteed_nfe(STEPS_COLD, t0), "NFE guarantee violated");
        rows.push(Row {
            label: format!("WS-DFM {kind} t0={t0}"),
            skl,
            nfe,
            secs_per_sample: elapsed.as_secs_f64() / n_eval as f64,
            // The paper's criterion: no worse than cold DFM (small slack for
            // sampling noise in the SKL estimate).
            ok: Some(skl <= cold_skl * 1.05),
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Row]) {
    common::print_table_header(
        "Table 1 (two moons): SKL / NFE",
        &["SKL", "NFE", "s/sample", "paper SKL", "paper NFE"],
    );
    for (i, r) in rows.iter().enumerate() {
        let (p_skl, p_nfe) = if i == 0 {
            (PAPER_COLD_SKL, STEPS_COLD)
        } else {
            let (_, _, ps, pn) = PAPER_ROWS[i - 1];
            (ps, pn)
        };
        let mark = match r.ok {
            None => String::new(),
            Some(true) => " ok".into(),
            Some(false) => " X".into(),
        };
        common::print_row(
            &format!("{}{}", r.label, mark),
            &[
                format!("{:.3}", r.skl),
                format!("{}", r.nfe),
                format!("{:.4}", r.secs_per_sample),
                format!("{p_skl:.2}"),
                format!("{p_nfe}"),
            ],
        );
    }
}

/// One adaptive-vs-static comparison row (§Control): same draft model,
/// same artifact, NFE and SKL under the static floor t0 vs the scored
/// controller's per-bundle choice.
#[derive(Debug, Clone)]
pub struct ControlRow {
    pub label: String,
    pub mode: &'static str,
    pub t0: f64,
    pub skl: f64,
    pub nfe: usize,
}

/// The guarantee-floor demonstration (acceptance criterion): for each
/// two-moons draft quality, run the *same* WS artifact once with the
/// static floor `t0` and once under the `scored` controller. The
/// adaptive NFE must never exceed the static-`t0_min` budget
/// `guaranteed_nfe(STEPS_COLD, t0_min)` — asserted here, not just
/// printed. Artifacts per kind are the lowest-t0 (floor) tags so every
/// evaluation time stays inside the model's trained range.
pub fn run_control(env: &Env, n_eval: usize, seed: u64) -> Result<Vec<ControlRow>> {
    use crate::config::ControlConfig;
    use crate::control::Controller;

    let mut rng = Pcg64::new(seed ^ 0x7a1);
    let target = two_moons::sample_batch(n_eval, &mut rng);
    let cfg = ControlConfig { mode: "scored".into(), ..ControlConfig::default() };
    let budget = guaranteed_nfe(STEPS_COLD, cfg.t0_min);

    // (kind, floor t0 with a trained artifact).
    let floors: [(&str, f64); 3] = [("good", 0.8), ("fair", 0.5), ("poor", 0.35)];
    let mut rows = Vec::new();
    for (kind, floor_t0) in floors {
        let tag = common::ws_tag_draft(kind, floor_t0);
        let draft = DraftSpec::Mixture(DraftKind::parse(kind).unwrap());
        let skl_of = |samples: &[Vec<i32>]| {
            let pts: Vec<[i32; 2]> = samples.iter().map(|s| [s[0], s[1]]).collect();
            skl_points(&target, &pts)
        };

        let (samples, nfe, _) = env.run_system(
            "two_moons",
            &tag,
            draft,
            floor_t0,
            STEPS_COLD,
            WarpMode::Literal,
            n_eval,
            seed + 1,
        )?;
        assert!(nfe <= budget, "static {kind}: NFE {nfe} exceeds floor budget {budget}");
        rows.push(ControlRow {
            label: format!("{kind} (tag {tag})"),
            mode: "static",
            t0: floor_t0,
            skl: skl_of(&samples),
            nfe,
        });

        let controller = Controller::from_config(&cfg)?;
        let (samples, nfe, t0_used, _) = env.run_system_with_controller(
            "two_moons",
            &tag,
            draft,
            floor_t0,
            STEPS_COLD,
            WarpMode::Literal,
            n_eval,
            seed + 1,
            controller,
        )?;
        assert!(nfe <= budget, "scored {kind}: NFE {nfe} exceeds floor budget {budget}");
        rows.push(ControlRow {
            label: format!("{kind} (tag {tag})"),
            mode: "scored",
            t0: t0_used,
            skl: skl_of(&samples),
            nfe,
        });
    }
    Ok(rows)
}

pub fn print_control(rows: &[ControlRow]) {
    let budget = guaranteed_nfe(STEPS_COLD, crate::config::ControlConfig::default().t0_min);
    common::print_table_header(
        &format!("Table 1b (control): static vs scored t0 — NFE budget {budget}"),
        &["mode", "t0", "SKL", "NFE"],
    );
    for r in rows {
        common::print_row(
            &r.label,
            &[
                r.mode.to_string(),
                format!("{:.2}", r.t0),
                format!("{:.3}", r.skl),
                format!("{}", r.nfe),
            ],
        );
    }
}

/// Fig 4 + Fig 5 data dumps (CSV histograms and generation traces).
pub fn dump_figures(env: &Env, out_dir: &std::path::Path, seed: u64) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let n = 4096;
    let mut rng = Pcg64::new(seed);

    // Fig 4: target / noise / draft distributions as point CSVs.
    let dump_pts = |name: &str, pts: &[[i32; 2]]| -> Result<()> {
        let mut f = std::fs::File::create(out_dir.join(name))?;
        writeln!(f, "x,y")?;
        for p in pts {
            writeln!(f, "{},{}", p[0], p[1])?;
        }
        Ok(())
    };
    dump_pts("fig4_a_target.csv", &two_moons::sample_batch(n, &mut rng))?;
    let noise: Vec<[i32; 2]> =
        (0..n).map(|_| [rng.below(128) as i32, rng.below(128) as i32]).collect();
    dump_pts("fig4_b_noise.csv", &noise)?;
    for (panel, kind) in [("c", DraftKind::Good), ("d", DraftKind::Fair), ("e", DraftKind::Poor)] {
        dump_pts(
            &format!("fig4_{panel}_draft_{}.csv", kind.name()),
            &two_moons::draft_batch(kind, n, &mut rng),
        )?;
    }

    // Fig 5: generation traces (cold + best WS per draft model).
    let trace_cfgs: [(&str, &str, f64, DraftSpec); 4] = [
        ("fig5_a_cold.csv", "cold", 0.0, DraftSpec::Noise),
        ("fig5_b_good_t080.csv", "ws_good_t080", 0.8, DraftSpec::Mixture(DraftKind::Good)),
        ("fig5_c_fair_t050.csv", "ws_fair_t050", 0.5, DraftSpec::Mixture(DraftKind::Fair)),
        ("fig5_d_poor_t035.csv", "ws_poor_t035", 0.35, DraftSpec::Mixture(DraftKind::Poor)),
    ];
    for (file, tag, t0, draft) in trace_cfgs {
        let meta = env.manifest.find_step("two_moons", tag, 1024)?;
        let init = match draft {
            DraftSpec::Noise => {
                let d = crate::draft::NoiseDraft { vocab: meta.vocab };
                crate::draft::Draft::generate(&d, 1024, 2, &mut rng)?
            }
            DraftSpec::Mixture(kind) => {
                let d = crate::draft::MixtureDraft { draft_kind: kind };
                crate::draft::Draft::generate(&d, 1024, 2, &mut rng)?
            }
            _ => unreachable!(),
        };
        let params = SamplerParams {
            artifact: meta.name.clone(),
            steps_cold: STEPS_COLD,
            t0,
            warp_mode: WarpMode::Literal,
        };
        let out = sample_warm(&env.engine, &params, init, &mut rng, true)?;
        out.trace.unwrap().write_points_csv(&out_dir.join(file))?;
    }
    println!("figure data written to {out_dir:?}");
    Ok(())
}

/// CLI entry (`wsfm bench-table1`).
pub fn main(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm bench-table1", "two-moons SKL/NFE (paper Table 1)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("n", "4096", "eval samples per system")
        .opt("seed", "0", "rng seed")
        .opt("warp", "literal", "update rule (literal|exact)")
        .opt("out", "out", "figure output directory")
        .flag("dump-figures", "also dump Fig 4/5 CSVs");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let env = Env::load(args.get("artifacts"))?;
    let n = args.get_usize("n").map_err(|m| anyhow::anyhow!(m))?;
    let seed = args.get_u64("seed").map_err(|m| anyhow::anyhow!(m))?;
    let rows = run_with_warp(&env, n, seed, WarpMode::parse(args.get("warp"))?)?;
    print(&rows);
    // Adaptive-vs-static guarantee-floor demonstration (§Control).
    let control = run_control(&env, n, seed)?;
    print_control(&control);
    if args.flag("dump-figures") {
        dump_figures(&env, std::path::Path::new(args.get("out")), 1)?;
    }
    env.engine.shutdown();
    Ok(())
}
