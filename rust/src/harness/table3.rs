//! Paper Table 3 + Fig 14: synth-wiki perplexity / entropy / time.
//!
//! Same harness as Table 2 (shared `run_text`), word-level domain: KN word
//! 3-gram evaluator on the held-out wiki corpus, perplexity instead of NLL.

use crate::data::corpus::load_i32_stream;
use crate::data::tokenizer::WordTokenizer;
use crate::harness::common::Env;
use crate::harness::table2::{dump_samples_generic, run_text, TextBenchCfg};
use crate::util::cli::Cli;
use anyhow::{Context, Result};

/// Paper Table 3 reference: (system, perplexity, entropy, seconds).
pub const PAPER: &[(&str, f64, f64, f64)] = &[
    ("LSTM", 171.23, 7.56, 0.0),
    ("Original DFM", 69.06, 7.42, 8.33),
    ("WS-DFM t0=0.8", 67.86, 7.19, 1.70),
    ("WS-DFM t0=0.5", 64.68, 7.16, 4.20),
    ("Refined (oracle)", 32.88, 7.14, 0.0),
];

/// CLI entry (`wsfm bench-table3`).
pub fn main(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm bench-table3", "wiki perplexity (paper Table 3)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("n", "48", "sentences per system")
        .opt("steps", "256", "cold-run step count (paper: 1024)")
        .opt("seed", "0", "rng seed")
        .opt("out", "out", "sample output directory")
        .flag("dump-samples", "also dump Fig 14 sample texts");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let env = Env::load(args.get("artifacts"))?;

    let eval_stream = load_i32_stream(&env.manifest.dir.join("wiki_eval.bin"))
        .context("loading wiki_eval.bin")?;
    let train_stream = load_i32_stream(&env.manifest.dir.join("wiki_corpus.bin"))?;

    let steps = args.get_usize("steps").map_err(|m| anyhow::anyhow!(m))?;
    let cfg = TextBenchCfg {
        domain: "wiki",
        eval_file: "wiki_eval.bin",
        eval_order: 3,
        refine_order: 3,
        vocab: 256,
        steps_cold: steps,
        n_eval: args.get_usize("n").map_err(|m| anyhow::anyhow!(m))?,
        seed: args.get_u64("seed").map_err(|m| anyhow::anyhow!(m))?,
    };
    let rows = run_text(&env, &cfg, &eval_stream, &train_stream[..train_stream.len().min(150_000)])?;
    crate::harness::table2::print("Table 3 (synth-wiki)", &rows, PAPER, true);
    println!(
        "\nnote: steps_cold={} here (paper: 1024); orderings are the target\n(DESIGN.md §2).",
        steps
    );

    if args.flag("dump-samples") {
        let vocab_text = std::fs::read_to_string(env.manifest.dir.join("wiki_vocab.json"))?;
        let tok = WordTokenizer::from_json(&vocab_text)?;
        let out_dir = std::path::Path::new(args.get("out"));
        dump_samples_generic(&env, out_dir, "wiki", "fig14", steps, 7, &|s| tok.decode(s))?;
    }
    env.engine.shutdown();
    Ok(())
}
