//! `wsfm figures` — dump every paper figure's data in one pass:
//! Fig 4/5 (two moons), Fig 10/14 (texts), Fig 6-9 (images), Fig 11 (k-NN
//! refinement examples from the build-time pairing).

use crate::data::shapes;
use crate::data::tokenizer::{CharTokenizer, WordTokenizer};
use crate::harness::common::Env;
use crate::harness::{table1, table2, table4};
use crate::util::cli::Cli;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Fig 11: render the k-NN refinement examples recorded by the AOT
/// pipeline (`fig11_knn_<domain>.json` + the train-set images).
pub fn dump_fig11(env: &Env, out_dir: &Path, domain: &str, side: usize, channels: usize) -> Result<()> {
    let json_path = env.manifest.dir.join(format!("fig11_knn_{domain}.json"));
    let idx_json = Json::parse(&std::fs::read_to_string(&json_path).with_context(|| format!("{json_path:?}"))?)?;
    let train = crate::data::corpus::load_u8_matrix(
        &env.manifest.dir.join(format!("{domain}_train.bin")),
        side * side * channels,
    )?;
    std::fs::create_dir_all(out_dir)?;
    let gray = channels == 1;
    for (row, neighbors) in idx_json.as_arr().unwrap_or(&[]).iter().enumerate().take(4) {
        for (col, idx) in neighbors.as_arr().unwrap_or(&[]).iter().enumerate() {
            let i = idx.as_usize().context("bad index")?;
            let img = &train[i.min(train.len() - 1)];
            let ext = if gray { "pgm" } else { "ppm" };
            let path = out_dir.join(format!("fig11_{domain}_draft{row}_nn{col}.{ext}"));
            if gray {
                shapes::write_pgm(&path, img, side)?;
            } else {
                shapes::write_ppm(&path, img, side)?;
            }
        }
    }
    Ok(())
}

/// CLI entry (`wsfm figures`).
pub fn main(rest: &[String]) -> Result<()> {
    let cli = Cli::new("wsfm figures", "dump all paper-figure data")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("out", "out", "output directory")
        .opt("steps", "64", "cold-run step count for generation figures")
        .opt("text-steps", "256", "cold-run step count for text figures");
    let args = cli.parse(rest).map_err(|m| anyhow::anyhow!("{m}"))?;
    let env = Env::load(args.get("artifacts"))?;
    let out = Path::new(args.get("out"));
    let steps = args.get_usize("steps").map_err(|m| anyhow::anyhow!(m))?;
    let text_steps = args.get_usize("text-steps").map_err(|m| anyhow::anyhow!(m))?;

    println!("[figures] two moons (Fig 4/5)...");
    table1::dump_figures(&env, out, 1)?;

    println!("[figures] text samples (Fig 10/14)...");
    table2::dump_samples(&env, out, text_steps, 7)?;
    let vocab_text = std::fs::read_to_string(env.manifest.dir.join("wiki_vocab.json"))?;
    let wtok = WordTokenizer::from_json(&vocab_text)?;
    table2::dump_samples_generic(&env, out, "wiki", "fig14", text_steps, 7, &|s| wtok.decode(s))?;
    // Keep the char tokenizer referenced for doc parity.
    let _ = CharTokenizer;

    println!("[figures] images (Fig 6-9)...");
    let gray_cfg = table4::ImageCfg {
        domain: "img_gray",
        side: shapes::GRAY_SIDE,
        channels: 1,
        steps_cold: steps,
        n_eval: 4,
        seed: 0,
    };
    table4::dump_figures(&env, out, &gray_cfg)?;
    let color_cfg = table4::ImageCfg {
        domain: "img_color",
        side: shapes::COLOR_SIDE,
        channels: 3,
        steps_cold: steps,
        n_eval: 4,
        seed: 0,
    };
    table4::dump_figures(&env, out, &color_cfg)?;

    println!("[figures] k-NN refinement examples (Fig 11)...");
    dump_fig11(&env, out, "img_gray", shapes::GRAY_SIDE, 1)?;
    dump_fig11(&env, out, "img_color", shapes::COLOR_SIDE, 3)?;

    println!("all figure data in {out:?}");
    env.engine.shutdown();
    Ok(())
}
