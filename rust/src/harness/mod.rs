//! Experiment harnesses: one per paper table/figure (DESIGN.md §4).
//!
//! Each harness regenerates the corresponding table rows side-by-side with
//! the paper's reported values. Entry points are shared by the `wsfm`
//! subcommands (`bench-table1`...) and the cargo bench binaries
//! (`rust/benches/*.rs`).

pub mod common;
pub mod figures;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
