//! Shared harness plumbing: engine setup, system execution, oracle text
//! refinement (the Rust-side mirror of the build-time refiner), and row
//! formatting.

use crate::cascade::Cascade;
use crate::control::Controller;
use crate::coordinator::request::{CascadeInfo, DraftSpec, GenRequest};
use crate::coordinator::Scheduler;
use crate::core::rng::Pcg64;
use crate::core::schedule::WarpMode;
use crate::draft::{Draft, DraftNoise, HloDraft, MixtureDraft, NoiseDraft};
use crate::eval::ngram::NgramLM;
use crate::metrics::ServingMetrics;
use crate::runtime::{EngineHandle, Executor, Manifest};
use anyhow::{Context, Result};
use std::path::Path;
use std::time::{Duration, Instant};

/// Loaded environment for a harness run.
pub struct Env {
    pub manifest: Manifest,
    pub engine: EngineHandle,
    pub metrics: ServingMetrics,
}

impl Env {
    pub fn load(artifacts: &str) -> Result<Env> {
        let manifest = Manifest::load(Path::new(artifacts))?;
        let engine = EngineHandle::spawn(manifest.clone())?;
        Ok(Env { manifest, engine, metrics: ServingMetrics::default() })
    }

    pub fn scheduler(&self) -> Scheduler<'_> {
        // Harness runs use config-seed 0; per-system determinism comes
        // from the request seed via the bundle-substream derivation.
        Scheduler::new(&self.engine, &self.manifest, &self.metrics, 0)
    }

    /// Run one "system" (a tag + draft + t0 triple) for `n` samples.
    /// Returns (samples, nfe, refine wall-clock).
    #[allow(clippy::too_many_arguments)]
    pub fn run_system(
        &self,
        domain: &str,
        tag: &str,
        draft: DraftSpec,
        t0: f64,
        steps_cold: usize,
        warp: WarpMode,
        n: usize,
        seed: u64,
    ) -> Result<(Vec<Vec<i32>>, usize, Duration)> {
        let req = GenRequest {
            id: 0,
            domain: domain.to_string(),
            tag: tag.to_string(),
            draft,
            n_samples: n,
            t0,
            steps_cold,
            warp_mode: warp,
            seed,
            timing: false,
            submitted: Instant::now(),
        };
        let resp = self.scheduler().run_single(req)?;
        Ok((resp.samples, resp.nfe, resp.refine_time))
    }

    /// [`Env::run_system`] under an explicit warm-start controller
    /// (the Table 1 adaptive-vs-static rows). Also returns the t0 the
    /// controller actually chose.
    #[allow(clippy::too_many_arguments)]
    pub fn run_system_with_controller(
        &self,
        domain: &str,
        tag: &str,
        draft: DraftSpec,
        t0: f64,
        steps_cold: usize,
        warp: WarpMode,
        n: usize,
        seed: u64,
        controller: Controller,
    ) -> Result<(Vec<Vec<i32>>, usize, f64, Duration)> {
        let req = GenRequest {
            id: 0,
            domain: domain.to_string(),
            tag: tag.to_string(),
            draft,
            n_samples: n,
            t0,
            steps_cold,
            warp_mode: warp,
            seed,
            timing: false,
            submitted: Instant::now(),
        };
        let scheduler =
            Scheduler::with_controller(&self.engine, &self.manifest, &self.metrics, 0, controller);
        let resp = scheduler.run_single(req)?;
        Ok((resp.samples, resp.nfe, resp.t0_used, resp.refine_time))
    }

    /// [`Env::run_system`] under explicit controller + cascade policies
    /// (the Tables 2/3 cascade rows). Returns the samples, worst-chunk
    /// total NFE, the t0 used, the cascade stage accounting, and the
    /// refine wall-clock.
    #[allow(clippy::too_many_arguments)]
    pub fn run_system_cascade(
        &self,
        domain: &str,
        tag: &str,
        draft: DraftSpec,
        t0: f64,
        steps_cold: usize,
        warp: WarpMode,
        n: usize,
        seed: u64,
        controller: Controller,
        cascade: Cascade,
    ) -> Result<(Vec<Vec<i32>>, usize, f64, Option<CascadeInfo>, Duration)> {
        let req = GenRequest {
            id: 0,
            domain: domain.to_string(),
            tag: tag.to_string(),
            draft,
            n_samples: n,
            t0,
            steps_cold,
            warp_mode: warp,
            seed,
            timing: false,
            submitted: Instant::now(),
        };
        let scheduler = Scheduler::with_policies(
            &self.engine,
            &self.manifest,
            &self.metrics,
            0,
            controller,
            cascade,
        );
        let resp = scheduler.run_single(req)?;
        Ok((resp.samples, resp.nfe, resp.t0_used, resp.cascade, resp.refine_time))
    }

    /// Generate `n` draft-only samples (the "LSTM"/"DC-GAN" table rows),
    /// returning the samples and total wall-clock.
    pub fn run_draft_only(
        &self,
        domain: &str,
        draft: DraftSpec,
        n: usize,
        seed: u64,
    ) -> Result<(Vec<Vec<i32>>, Duration)> {
        let first = self
            .manifest
            .for_domain(domain)
            .first()
            .cloned()
            .cloned()
            .with_context(|| format!("no artifacts for {domain}"))?;
        let (seq_len, vocab) = (first.seq_len, first.vocab);
        let mut rng = Pcg64::new(seed);
        let start = Instant::now();
        let mut rows = Vec::with_capacity(n);
        match draft {
            DraftSpec::Noise => {
                let d = NoiseDraft { vocab };
                let tb = d.generate(n, seq_len, &mut rng)?;
                for i in 0..n {
                    rows.push(tb.row(i).to_vec());
                }
            }
            DraftSpec::Mixture(kind) => {
                let d = MixtureDraft { draft_kind: kind };
                let tb = d.generate(n, seq_len, &mut rng)?;
                for i in 0..n {
                    rows.push(tb.row(i).to_vec());
                }
            }
            DraftSpec::Lstm | DraftSpec::Pca => {
                let kind = if draft == DraftSpec::Lstm { "lstm" } else { "pca" };
                // Use the largest compiled draft batch.
                let mut batches: Vec<usize> = self
                    .manifest
                    .artifacts
                    .iter()
                    .filter(|a| a.domain == domain && a.kind == "draft" && a.draft.as_deref() == Some(kind))
                    .map(|a| a.batch)
                    .collect();
                batches.sort_unstable();
                let b = *batches.last().with_context(|| format!("no {kind} drafts for {domain}"))?;
                let meta = self.manifest.find_draft(domain, kind, b)?;
                let noise =
                    if kind == "lstm" { DraftNoise::Gumbel } else { DraftNoise::Gaussian };
                let d = HloDraft::new(&self.engine as &dyn Executor, meta.name.clone(), noise);
                while rows.len() < n {
                    let tb = d.generate(b, seq_len, &mut rng)?;
                    for i in 0..b.min(n - rows.len()) {
                        rows.push(tb.row(i).to_vec());
                    }
                }
            }
        }
        Ok((rows, start.elapsed()))
    }
}

/// WS tag naming convention shared with the AOT pipeline.
pub fn ws_tag(t0: f64) -> String {
    format!("ws_t{:03}", (t0 * 100.0).round() as u32)
}

pub fn ws_tag_draft(kind: &str, t0: f64) -> String {
    format!("ws_{kind}_t{:03}", (t0 * 100.0).round() as u32)
}

/// Oracle text refiner (Rust mirror of `python/compile/refine.py`): resample
/// the lowest-likelihood positions under `lm`, bounded edit budget. Used for
/// the "Refined by <oracle>" table rows.
pub fn oracle_refine(seq: &[i32], lm: &NgramLM, rng: &mut Pcg64, max_edit_frac: f64) -> Vec<i32> {
    let mut out: Vec<i32> = seq.to_vec();
    let order = lm.order;
    let budget = ((seq.len() as f64) * max_edit_frac).max(1.0) as usize;
    // Score positions.
    let mut scored: Vec<(usize, f64)> = (order - 1..out.len())
        .map(|i| {
            let lo = i.saturating_sub(order - 1);
            (i, lm.prob(&out[lo..i], out[i]).max(1e-12).ln())
        })
        .collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for &(pos, old_lp) in scored.iter().take(budget) {
        let lo = pos.saturating_sub(order - 1);
        let ctx: Vec<i32> = out[lo..pos].to_vec();
        // Low-temperature Gumbel-max over the LM conditional.
        let mut best_tok = out[pos];
        let mut best_score = f64::NEG_INFINITY;
        for tok in 0..lm.vocab as i32 {
            let lp = lm.prob(&ctx, tok).max(1e-12).ln();
            let score = lp / 0.7 + rng.gumbel();
            if score > best_score {
                best_score = score;
                best_tok = tok;
            }
        }
        let new_lp = lm.prob(&ctx, best_tok).max(1e-12).ln();
        if new_lp > old_lp {
            out[pos] = best_tok;
        }
    }
    out
}

/// Table formatting: fixed-width row with a paper-reference column.
pub fn print_table_header(title: &str, cols: &[&str]) {
    println!("\n=== {title} ===");
    let head: Vec<String> = cols.iter().map(|c| format!("{c:>14}")).collect();
    println!("{:<34}{}", "system", head.join(""));
    println!("{}", "-".repeat(34 + 14 * cols.len()));
}

pub fn print_row(label: &str, cells: &[String]) {
    let body: Vec<String> = cells.iter().map(|c| format!("{c:>14}")).collect();
    println!("{label:<34}{}", body.join(""));
}

pub fn fmt_secs(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ws_tags_match_aot_convention() {
        assert_eq!(ws_tag(0.8), "ws_t080");
        assert_eq!(ws_tag(0.5), "ws_t050");
        assert_eq!(ws_tag(0.65), "ws_t065");
        assert_eq!(ws_tag(0.95), "ws_t095");
        assert_eq!(ws_tag(0.35), "ws_t035");
        assert_eq!(ws_tag_draft("good", 0.95), "ws_good_t095");
        assert_eq!(ws_tag_draft("poor", 0.35), "ws_poor_t035");
    }

    #[test]
    fn oracle_refine_improves_likelihood_and_bounds_edits() {
        // Train an LM on structured text, refine noise toward it.
        let stream: Vec<i32> = (0..4000).map(|i| (i % 4) as i32).collect();
        let lm = NgramLM::fit(&stream, 3, 8);
        let mut rng = Pcg64::new(0);
        let noisy: Vec<i32> = (0..64).map(|_| rng.below(8) as i32).collect();
        let refined = oracle_refine(&noisy, &lm, &mut rng, 0.35);
        assert_eq!(refined.len(), noisy.len());
        let edits = noisy.iter().zip(&refined).filter(|(a, b)| a != b).count();
        assert!(edits <= (64.0 * 0.35) as usize + 1, "edits {edits}");
        assert!(lm.nll(&refined) <= lm.nll(&noisy) + 1e-9, "refinement should not hurt NLL");
    }
}
