//! Cascade refinement: multi-segment warm-start ladders with
//! mid-trajectory quality gates and early exit.
//!
//! The stack used to spend a bundle's whole refinement budget in one
//! shot: one t0, one uninterrupted Euler segment to `t = 1`. But drafts
//! differ in how much refinement they actually need (FastFlow's adaptive
//! step allocation; Distilled Decoding's observation that few-step
//! drafts are often already acceptable), so this subsystem splits the
//! run into an ordered **ladder of resumable segments**
//! `[(t_start, t_end, artifact)]` ([`planner`]), executes each as a
//! windowed engine loop ([`executor`], via the segmented
//! `runtime::engine::LoopSpec`), scores the intermediate token state
//! with the [`crate::control`] draft-quality proxies between segments,
//! and **exits early** when the quality gate passes — the remaining
//! segments are simply never paid for.
//!
//! ## The guarantee is untouched
//!
//! Segment boundaries snap to the unsplit schedule's step grid
//! (`core::schedule::grid_index`), so the executed segments are a prefix
//! partition of the unsplit run: the summed per-stage NFE equals the
//! unsplit `guaranteed_nfe(steps_cold, t0)` when every gate fails and is
//! strictly smaller on early exit. Combined with the controller's
//! `t0 >= t0_min` clamp, **total NFE never exceeds
//! `guaranteed_nfe(steps_cold, t0_min)`** — the paper's floor — in any
//! cascade mode (asserted in the scheduler and pinned by tests).
//!
//! ## Bitwise determinism
//!
//! Every categorical draw keys on `(run seed, absolute step, row)`, so a
//! run split into k segments produces exactly the unsplit run's tokens —
//! `fixed` mode is bitwise-identical to `off`, and a gated run's output
//! is the exact intermediate state of the unsplit trajectory. Gates are
//! pure functions of (tokens, config), so cascade decisions are
//! deterministic across pipeline depth, draft workers, and fleet
//! replicas (pinned by the coordinator sweep tests). Segments may hop
//! between fleet replicas; the fleet's artifact-affinity routing makes
//! resume-on-same-replica the common case, and hopping never changes
//! tokens.
//!
//! `cascade.mode = off` (the default) bypasses this module entirely —
//! byte-for-byte the pre-cascade wire behaviour.

pub mod executor;
pub mod planner;

pub use executor::{run_segments, CascadeOutcome, StageOutcome};
pub use planner::{plan_ladder, Segment};

use crate::config::CascadeConfig;
use anyhow::Result;

/// How a bundle's refinement budget is spent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CascadeMode {
    /// One uninterrupted segment (legacy behaviour, the default).
    Off,
    /// Run every ladder segment; no gates. Tokens are bitwise-identical
    /// to `Off` — the mode exists to exercise (and pin) segmented
    /// execution in production configurations.
    Fixed,
    /// Score the intermediate state after each non-final segment and
    /// exit early once the quality gate passes.
    Gated,
}

impl CascadeMode {
    pub fn parse(s: &str) -> Result<CascadeMode> {
        match s {
            "off" => Ok(CascadeMode::Off),
            "fixed" => Ok(CascadeMode::Fixed),
            "gated" => Ok(CascadeMode::Gated),
            _ => anyhow::bail!("unknown cascade mode {s:?} (off|fixed|gated)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CascadeMode::Off => "off",
            CascadeMode::Fixed => "fixed",
            CascadeMode::Gated => "gated",
        }
    }
}

/// The per-bundle cascade policy. Cheap to clone (pure data); each
/// scheduler instance owns one, so clones plan and gate identically on
/// every stage thread (the determinism contract).
#[derive(Debug, Clone)]
pub struct Cascade {
    mode: CascadeMode,
    ladder: Vec<f64>,
    gate_threshold: f64,
}

impl Cascade {
    /// The legacy behaviour: no cascade, one uninterrupted segment.
    pub fn off() -> Cascade {
        Cascade { mode: CascadeMode::Off, ladder: Vec::new(), gate_threshold: 1.0 }
    }

    /// Build from a (validated) [`CascadeConfig`]. Non-finite or
    /// out-of-range ladder entries are dropped defensively
    /// (`config::validate` rejects them; direct callers may skip it).
    pub fn from_config(cfg: &CascadeConfig) -> Result<Cascade> {
        let mode = CascadeMode::parse(&cfg.mode)?;
        let mut ladder: Vec<f64> =
            cfg.ladder.iter().copied().filter(|b| b.is_finite() && *b > 0.0 && *b < 1.0).collect();
        ladder.sort_by(|a, b| a.partial_cmp(b).expect("finite ladder has no NaN"));
        ladder.dedup();
        if !cfg.gate_threshold.is_finite() {
            anyhow::bail!("cascade.gate_threshold must be finite");
        }
        Ok(Cascade { mode, ladder, gate_threshold: cfg.gate_threshold.clamp(0.0, 1.0) })
    }

    pub fn mode(&self) -> CascadeMode {
        self.mode
    }

    pub fn is_off(&self) -> bool {
        self.mode == CascadeMode::Off
    }

    /// The configured boundary ladder (ascending, deduped) — recorded
    /// per bundle by the decision ledger.
    pub fn ladder(&self) -> &[f64] {
        &self.ladder
    }

    /// The gate threshold [`executor::run_segments`] should apply —
    /// `None` outside `gated` mode (no scoring work is done at all).
    pub fn gate_threshold(&self) -> Option<f64> {
        (self.mode == CascadeMode::Gated).then_some(self.gate_threshold)
    }

    /// Plan the segment ladder for one chunk: the configured boundaries
    /// snapped onto the `(steps_cold, run_t0)` grid, every segment
    /// refining on `artifact`. Always returns at least one segment.
    pub fn plan(&self, steps_cold: usize, run_t0: f64, artifact: &str) -> Vec<Segment> {
        match self.mode {
            CascadeMode::Off => plan_ladder(&[], steps_cold, run_t0, artifact),
            _ => plan_ladder(&self.ladder, steps_cold, run_t0, artifact),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [CascadeMode::Off, CascadeMode::Fixed, CascadeMode::Gated] {
            assert_eq!(CascadeMode::parse(m.name()).unwrap(), m);
        }
        assert!(CascadeMode::parse("diagonal").is_err());
    }

    #[test]
    fn off_policy_plans_one_segment() {
        let c = Cascade::off();
        assert!(c.is_off());
        assert_eq!(c.gate_threshold(), None);
        let plan = c.plan(10, 0.5, "a");
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].nfe(), 5);
    }

    #[test]
    fn from_config_sorts_and_filters_ladder() {
        let cfg = CascadeConfig {
            mode: "gated".into(),
            ladder: vec![0.9, 0.6, f64::NAN, 0.6, -1.0, 1.5],
            gate_threshold: 0.4,
        };
        let c = Cascade::from_config(&cfg).unwrap();
        assert_eq!(c.ladder, vec![0.6, 0.9]);
        assert_eq!(c.gate_threshold(), Some(0.4));
        assert_eq!(c.plan(10, 0.5, "a").len(), 3);
        // Fixed mode still plans segments but never gates.
        let fixed = Cascade::from_config(&CascadeConfig {
            mode: "fixed".into(),
            ..CascadeConfig::default()
        })
        .unwrap();
        assert_eq!(fixed.gate_threshold(), None);
        assert!(fixed.plan(10, 0.5, "a").len() > 1);
        // Invalid mode errors; non-finite threshold errors.
        assert!(Cascade::from_config(&CascadeConfig {
            mode: "warp".into(),
            ..CascadeConfig::default()
        })
        .is_err());
        assert!(Cascade::from_config(&CascadeConfig {
            gate_threshold: f64::NAN,
            ..CascadeConfig::default()
        })
        .is_err());
    }
}
