//! The cascade executor: drive a planned segment ladder through an
//! [`Executor`], scoring the intermediate state between segments and
//! exiting early when the quality gate passes.

use crate::control::proxy_score;
use crate::runtime::engine::{Executor, LoopScratch, LoopSpec};
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

use super::planner::Segment;

/// What one executed stage of the cascade did.
#[derive(Debug, Clone, PartialEq)]
pub struct StageOutcome {
    pub t_start: f64,
    pub t_end: f64,
    /// Denoiser evaluations this stage performed (== its segment's NFE).
    pub nfe: usize,
    /// The gate's quality score of the state *after* this stage (`None`
    /// for the final planned stage and outside gated mode — no scoring
    /// work is done where no gate can fire).
    pub score: Option<f64>,
    /// Wall-clock of the gate evaluation.
    pub gate_eval: Option<Duration>,
    /// Wall-clock of the stage's engine dispatch (the per-segment entry
    /// of the opt-in timing breakdown). `Duration::ZERO` on the composed
    /// path, where a shared cross-bundle step's wall-clock is not
    /// attributable to one bundle; purely observational either way —
    /// never an input to gating or scheduling.
    pub elapsed: Duration,
}

/// The executed cascade for one chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct CascadeOutcome {
    /// Executed stages, in order (a prefix of the plan).
    pub stages: Vec<StageOutcome>,
    /// How many stages the plan held.
    pub planned_stages: usize,
    /// Whether a gate passed before the final stage.
    pub early_exit: bool,
}

/// Score a chunk's intermediate token state for a quality gate: the
/// first `useful_rows` rows (padding never votes) go through the
/// [`crate::control`] proxies. Returns `(score, gate wall-clock)`.
///
/// The single gate implementation shared by [`run_segments`] and the
/// step-level batch composer ([`crate::coordinator::composer`]) — gates
/// are pure functions of (tokens, config), so both paths deciding from
/// the same state exit at the same stage (the determinism contract).
pub(crate) fn eval_gate(
    tokens: &[i32],
    useful_rows: usize,
    seq_len: usize,
    vocab: usize,
) -> (f64, Duration) {
    let gate_start = Instant::now();
    let rows: Vec<&[i32]> = tokens.chunks_exact(seq_len.max(1)).take(useful_rows).collect();
    let score = proxy_score(&rows, vocab);
    (score, gate_start.elapsed())
}

impl CascadeOutcome {
    pub fn stages_used(&self) -> usize {
        self.stages.len()
    }

    /// Summed NFE over executed stages — the quantity the guarantee
    /// bounds: `== ` the unsplit schedule's NFE when every stage ran,
    /// strictly smaller on early exit.
    pub fn total_nfe(&self) -> usize {
        self.stages.iter().map(|s| s.nfe).sum()
    }
}

/// Run a planned ladder over `tokens` (resampled in place, exactly as
/// `Executor::run_loop` does).
///
/// Each segment is one `run_loop` dispatch with the shared `seed` — the
/// engine's absolute-step substreams make the concatenation
/// bitwise-identical to the unsplit run, and (through a fleet executor)
/// each dispatch routes independently, with artifact affinity making
/// resume-on-same-replica the common case. After every non-final
/// segment, if `gate_threshold` is set, the first `useful_rows` rows
/// (padding never votes) are scored with the [`crate::control`] proxies;
/// a score `>= threshold` stops the cascade — the remaining segments are
/// never executed, which is the only way the cascade changes NFE.
#[allow(clippy::too_many_arguments)]
pub fn run_segments(
    exec: &dyn Executor,
    plan: &[Segment],
    steps_cold: usize,
    run_t0: f64,
    warp: f32,
    seed: u64,
    tokens: &mut Vec<i32>,
    useful_rows: usize,
    seq_len: usize,
    vocab: usize,
    gate_threshold: Option<f64>,
    scratch: &mut LoopScratch,
) -> Result<CascadeOutcome> {
    if plan.is_empty() {
        bail!("empty cascade plan");
    }
    let mut stages = Vec::with_capacity(plan.len());
    let mut early_exit = false;
    for (si, seg) in plan.iter().enumerate() {
        let mut spec = LoopSpec::full(seg.artifact.clone(), steps_cold, run_t0, warp, seed, false);
        spec.t_start = seg.t_start;
        spec.t_end = seg.t_end;
        let seg_start = Instant::now();
        let report = exec.run_loop(&spec, tokens, scratch)?;
        let seg_elapsed = seg_start.elapsed();
        debug_assert_eq!(report.nfe, seg.nfe(), "segment schedule diverged from plan");
        let mut stage = StageOutcome {
            t_start: seg.t_start,
            t_end: seg.t_end,
            nfe: report.nfe,
            score: None,
            gate_eval: None,
            elapsed: seg_elapsed,
        };
        let is_last = si + 1 == plan.len();
        if !is_last {
            if let Some(threshold) = gate_threshold {
                let (score, gate_elapsed) = eval_gate(tokens, useful_rows, seq_len, vocab);
                stage.score = Some(score);
                stage.gate_eval = Some(gate_elapsed);
                if score >= threshold {
                    early_exit = true;
                    stages.push(stage);
                    break;
                }
            }
        }
        stages.push(stage);
    }
    Ok(CascadeOutcome { stages, planned_stages: plan.len(), early_exit })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::plan_ladder;
    use crate::coordinator::testutil::TestExec;
    use crate::core::schedule::guaranteed_nfe;

    const ART: &str = "mock_cold_step_b8";

    fn run(
        exec: &dyn Executor,
        ladder: &[f64],
        gate: Option<f64>,
        seed: u64,
    ) -> (Vec<i32>, CascadeOutcome) {
        let plan = plan_ladder(ladder, 10, 0.5, ART);
        let mut tokens = vec![2i32; 8 * 4];
        let mut scratch = LoopScratch::default();
        let outcome = run_segments(
            exec,
            &plan,
            10,
            0.5,
            1.0,
            seed,
            &mut tokens,
            8,
            4,
            6,
            gate,
            &mut scratch,
        )
        .unwrap();
        (tokens, outcome)
    }

    #[test]
    fn fixed_ladder_is_bitwise_identical_to_unsplit() {
        // seed-sensitive executor: equality is meaningful.
        let a = TestExec::stochastic(vec![1, 8], 4, 6, 1);
        let (unsplit, base) = run(&a, &[], None, 42);
        assert_eq!(base.stages_used(), 1);
        assert_eq!(base.total_nfe(), 5);
        for ladder in [&[0.75][..], &[0.6, 0.75, 0.9][..]] {
            let b = TestExec::stochastic(vec![1, 8], 4, 6, 1);
            let (split, outcome) = run(&b, ladder, None, 42);
            assert_eq!(split, unsplit, "ladder {ladder:?}");
            assert!(!outcome.early_exit);
            assert_eq!(outcome.stages_used(), outcome.planned_stages);
            assert_eq!(outcome.total_nfe(), 5, "no gates → full budget, tiled");
            assert!(outcome.stages.iter().all(|s| s.score.is_none()));
        }
        // A different seed still differs (the executor is genuinely
        // stochastic — the equality above is not vacuous).
        let c = TestExec::stochastic(vec![1, 8], 4, 6, 1);
        assert_ne!(run(&c, &[], None, 43).0, unsplit);
    }

    #[test]
    fn gate_pass_exits_early_and_saves_nfe() {
        // Threshold 0: every score passes → exit right after stage 1.
        let exec = TestExec::stochastic(vec![1, 8], 4, 6, 1);
        let (_, outcome) = run(&exec, &[0.75, 0.9], Some(0.0), 7);
        assert!(outcome.early_exit);
        assert_eq!(outcome.stages_used(), 1);
        assert_eq!(outcome.planned_stages, 3);
        assert_eq!(outcome.total_nfe(), 3, "only the [0.5, 0.8) segment ran");
        assert!(outcome.total_nfe() < guaranteed_nfe(10, 0.5));
        let s = &outcome.stages[0];
        assert!(s.score.is_some() && s.gate_eval.is_some());
        // An unreachable threshold behaves like fixed (scores recorded,
        // never passes, full budget spent).
        let exec2 = TestExec::stochastic(vec![1, 8], 4, 6, 1);
        let (_, full) = run(&exec2, &[0.75, 0.9], Some(1.0), 7);
        assert!(!full.early_exit);
        assert_eq!(full.stages_used(), 3);
        assert_eq!(full.total_nfe(), 5);
        // The final stage never pays for a gate it cannot fire.
        assert!(full.stages.last().unwrap().score.is_none());
    }

    #[test]
    fn early_exit_tokens_are_the_unsplit_intermediate_state() {
        // A gated exit returns exactly the unsplit trajectory's state at
        // the boundary — pinned by running just that prefix explicitly.
        let a = TestExec::stochastic(vec![1, 8], 4, 6, 1);
        let (gated, outcome) = run(&a, &[0.75], Some(0.0), 11);
        assert!(outcome.early_exit);
        let b = TestExec::stochastic(vec![1, 8], 4, 6, 1);
        let plan = plan_ladder(&[0.75], 10, 0.5, ART);
        let mut prefix = vec![2i32; 8 * 4];
        let mut scratch = LoopScratch::default();
        let mut spec = LoopSpec::full(ART.into(), 10, 0.5, 1.0, 11, false);
        spec.t_start = plan[0].t_start;
        spec.t_end = plan[0].t_end;
        b.run_loop(&spec, &mut prefix, &mut scratch).unwrap();
        assert_eq!(gated, prefix);
    }

    #[test]
    fn segments_resume_on_the_same_fleet_replica_by_affinity() {
        use crate::fleet::FleetHandle;
        use std::sync::Arc;
        let fleet = FleetHandle::from_executors(vec![
            Arc::new(TestExec::drift(vec![1, 8], 4, 6, 1)) as Arc<dyn Executor>,
            Arc::new(TestExec::drift(vec![1, 8], 4, 6, 1)) as Arc<dyn Executor>,
        ]);
        let (_, outcome) = run(&fleet, &[0.6, 0.75, 0.9], None, 3);
        assert_eq!(outcome.stages_used(), 4);
        // All four segment dispatches landed on replica 0: idle fleet,
        // lowest index first, then artifact affinity on every resume.
        assert_eq!(fleet.metrics().replica_dispatched[0].get(), 4);
        assert_eq!(fleet.metrics().replica_dispatched[1].get(), 0);
    }

    #[test]
    fn empty_plan_is_rejected() {
        let exec = TestExec::drift(vec![1, 8], 4, 6, 1);
        let mut tokens = vec![0i32; 8 * 4];
        let mut scratch = LoopScratch::default();
        assert!(run_segments(
            &exec,
            &[],
            10,
            0.5,
            1.0,
            0,
            &mut tokens,
            8,
            4,
            6,
            None,
            &mut scratch,
        )
        .is_err());
    }
}
