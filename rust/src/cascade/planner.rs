//! The cascade planner: snap a configured boundary ladder onto one
//! chunk's step grid, producing an ordered list of non-empty segments
//! that tile the unsplit schedule exactly.

use crate::core::schedule::{grid_index, guaranteed_nfe};

/// One planned refinement segment: the window `[t_start, t_end)` of the
/// unsplit run, in both time and absolute-step coordinates, plus the
/// step artifact that refines it. Carrying the artifact per segment
/// keeps the design open to per-stage artifacts (e.g. a ws model trained
/// at a later t0 for the tail of the ladder); today every segment of a
/// chunk uses the chunk's own artifact, which also makes the fleet's
/// artifact-affinity routing resume segments on the same replica in the
/// common case.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub t_start: f64,
    pub t_end: f64,
    pub artifact: String,
    /// Absolute index of this segment's first step in the unsplit run.
    pub step_start: usize,
    /// One past the absolute index of this segment's last step.
    pub step_end: usize,
}

impl Segment {
    /// Denoiser evaluations this segment performs.
    pub fn nfe(&self) -> usize {
        self.step_end - self.step_start
    }
}

/// Plan the ladder for a `(steps_cold, run_t0)` schedule: boundaries
/// outside `(run_t0, 1)` are dropped, the rest snap to the step grid
/// (`grid_index`, epsilon-robust), and cuts that would produce an empty
/// segment are merged away. The result always holds >= 1 segment, the
/// segments are consecutive (`step_end == next.step_start`), and their
/// NFEs sum to exactly `guaranteed_nfe(steps_cold, run_t0)` — planning
/// never changes the total budget, only where it can stop.
pub fn plan_ladder(
    boundaries: &[f64],
    steps_cold: usize,
    run_t0: f64,
    artifact: &str,
) -> Vec<Segment> {
    let n = guaranteed_nfe(steps_cold, run_t0);
    let h = 1.0 / steps_cold.max(1) as f64;
    // Cut list in (index, time) form; always starts at (0, run_t0) and
    // ends at (n, 1.0). Interior cut times are the snapped grid times, so
    // a segment's t_end maps back to exactly its step_end.
    let mut cuts: Vec<(usize, f64)> = vec![(0, run_t0)];
    for &b in boundaries {
        if !b.is_finite() || b <= run_t0 || b >= 1.0 {
            continue;
        }
        let idx = grid_index(steps_cold, run_t0, b);
        if idx > cuts.last().expect("cuts never empty").0 && idx < n {
            cuts.push((idx, run_t0 + idx as f64 * h));
        }
    }
    cuts.push((n, 1.0));
    cuts.windows(2)
        .map(|w| Segment {
            t_start: w[0].1,
            t_end: w[1].1,
            artifact: artifact.to_string(),
            step_start: w[0].0,
            step_end: w[1].0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total_nfe(plan: &[Segment]) -> usize {
        plan.iter().map(|s| s.nfe()).sum()
    }

    fn assert_tiles(plan: &[Segment], steps: usize, t0: f64) {
        assert!(!plan.is_empty());
        assert_eq!(plan[0].step_start, 0);
        assert_eq!(plan.last().unwrap().step_end, guaranteed_nfe(steps, t0));
        assert!((plan.last().unwrap().t_end - 1.0).abs() < 1e-12);
        for w in plan.windows(2) {
            assert_eq!(w[0].step_end, w[1].step_start, "segments must be consecutive");
            assert_eq!(w[0].t_end, w[1].t_start);
        }
        for s in plan {
            assert!(s.nfe() > 0, "empty segments must be merged away: {s:?}");
        }
        assert_eq!(total_nfe(plan), guaranteed_nfe(steps, t0));
    }

    #[test]
    fn empty_ladder_is_one_full_segment() {
        let plan = plan_ladder(&[], 10, 0.5, "art");
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0], Segment {
            t_start: 0.5,
            t_end: 1.0,
            artifact: "art".into(),
            step_start: 0,
            step_end: 5,
        });
        assert_tiles(&plan, 10, 0.5);
    }

    #[test]
    fn ladder_snaps_to_grid_and_tiles_exactly() {
        // t0 = 0.5, 10 cold steps → 5 evaluations at {0.5,…,0.9}. Cuts at
        // 0.75 and 0.9 snap to step indices 3 and 4.
        let plan = plan_ladder(&[0.75, 0.9], 10, 0.5, "a");
        assert_eq!(plan.len(), 3);
        assert_eq!((plan[0].step_start, plan[0].step_end), (0, 3));
        assert_eq!((plan[1].step_start, plan[1].step_end), (3, 4));
        assert_eq!((plan[2].step_start, plan[2].step_end), (4, 5));
        assert!((plan[0].t_end - 0.8).abs() < 1e-9, "snapped up to the grid: {}", plan[0].t_end);
        assert_tiles(&plan, 10, 0.5);
    }

    #[test]
    fn out_of_range_and_colliding_boundaries_drop() {
        // Boundaries at/below t0, at/above 1, non-finite, and ones that
        // snap onto the same grid index all merge away.
        let plan = plan_ladder(&[0.1, 0.5, 0.72, 0.74, 0.999, 1.0, f64::NAN], 10, 0.5, "a");
        // 0.72 and 0.74 both snap to index 3; 0.999 snaps to index 5 == n
        // (would leave an empty tail) and is dropped.
        assert_eq!(plan.len(), 2);
        assert_eq!((plan[0].step_start, plan[0].step_end), (0, 3));
        assert_eq!((plan[1].step_start, plan[1].step_end), (3, 5));
        assert_tiles(&plan, 10, 0.5);
    }

    #[test]
    fn plans_tile_for_assorted_grids() {
        for (steps, t0) in [(1usize, 0.0), (7, 0.33), (20, 0.8), (1024, 0.5), (20, 1.0 - 1e-9)] {
            for ladder in [&[][..], &[0.6, 0.75, 0.9][..], &[0.99][..], &[0.2, 0.4, 0.6, 0.8][..]] {
                let plan = plan_ladder(ladder, steps, t0, "a");
                assert_tiles(&plan, steps, t0);
            }
        }
    }
}
