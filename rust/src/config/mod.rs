//! Typed configuration for the serving stack.
//!
//! Defaults ← JSON config file (`--config path`) ← CLI overrides, in that
//! precedence order. The config is deliberately explicit: everything the
//! coordinator, batcher, and sampler consult lives here, and `validate()`
//! rejects inconsistent settings at startup rather than mid-request.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Top-level configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WsfmConfig {
    /// Directory containing the AOT artifacts + manifest.
    pub artifacts_dir: PathBuf,
    /// TCP listen address for `wsfm serve`.
    pub listen_addr: String,
    pub batcher: BatcherConfig,
    pub sampler: SamplerConfig,
    /// Bounded admission queue size (backpressure beyond this).
    pub queue_capacity: usize,
    /// Max bundles in flight across the DRAFT→REFINE pipeline. `1` runs
    /// the legacy serial path (admission thread executes bundles inline);
    /// `>= 2` lets drafting bundle N+1 overlap refining bundle N.
    pub pipeline_depth: usize,
    /// DRAFT-stage worker threads (only used when `pipeline_depth >= 2`).
    pub draft_workers: usize,
    /// Global RNG seed (per-bundle substreams are derived from it).
    pub seed: u64,
    /// Adaptive warm-start controller ([`crate::control`]).
    pub control: ControlConfig,
    /// Replicated executor fleet ([`crate::fleet`]).
    pub fleet: FleetConfig,
    /// Cascade refinement ladder ([`crate::cascade`]).
    pub cascade: CascadeConfig,
    /// Fault-tolerance envelope ([`crate::faults`], fleet health loop,
    /// refine watchdog, draft-fallback degradation).
    pub robustness: RobustnessConfig,
    /// Step-level batch composer ([`crate::coordinator::composer`]).
    pub composer: ComposerConfig,
    /// Wire codec negotiation ([`crate::server::codec`]).
    pub wire: WireConfig,
    /// Observability journals ([`crate::obs`]).
    pub obs: ObsConfig,
}

/// Observability tuning (`obs` subsystem).
///
/// Caps the bounded span/event journals ([`crate::obs`]) and gates
/// recording entirely. Purely observational: toggling any of these never
/// changes an output byte (pinned by the serving determinism sweep).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObsConfig {
    /// Record spans/events at all (default on; journal memory is bounded
    /// by the caps below either way, and recording is lock-cheap).
    pub enabled: bool,
    /// Span-journal ring capacity *per span kind* (oldest overwritten).
    pub span_cap: usize,
    /// Event-journal capacity (FIFO eviction; sequence numbers stay
    /// gap-free so consumers can detect eviction).
    pub event_cap: usize,
    /// Decision ledger + guarantee auditor ([`crate::obs::ledger`]).
    pub ledger: LedgerConfig,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: true, span_cap: 4096, event_cap: 1024, ledger: LedgerConfig::default() }
    }
}

/// Decision-ledger tuning (`obs.ledger` subsystem).
///
/// Every refined (or degraded) bundle appends one
/// [`crate::obs::ledger::DecisionRecord`] — what the controller/cascade
/// decided and what it cost — audited on append against the NFE
/// guarantee. Independent of `obs.enabled` (spans/events), so the
/// guarantee auditor can stay live with tracing off. Purely
/// observational: toggling never changes an output byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LedgerConfig {
    /// Record decisions at all (default on; the ring is bounded and an
    /// append is one audit + one lock-cheap push).
    pub enabled: bool,
    /// In-memory ring capacity (oldest records FIFO-evicted; the sink,
    /// when configured, still has them).
    pub cap: usize,
    /// Append-only JSONL sink path ("" = in-memory only). One record
    /// per line, flushed per append, so a crash mid-write loses at most
    /// the final line — `wsfm audit`/`wsfm replay` consume this file.
    pub path: String,
}

impl Default for LedgerConfig {
    fn default() -> Self {
        LedgerConfig { enabled: true, cap: 1024, path: String::new() }
    }
}

/// Wire-codec tuning (`wire` subsystem).
///
/// The server accepts the codecs listed in `codecs` when a client sends
/// `{"cmd":"hello","codecs":[...]}`, and starts every connection on
/// `default`. With `default = "json"` (the default) a client that never
/// sends a hello gets the legacy JSON-lines wire format byte-for-byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireConfig {
    /// Codec names the server will negotiate ("json", "binary").
    pub codecs: Vec<String>,
    /// Codec every connection starts on (before any hello).
    pub default: String,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            codecs: vec!["json".to_string(), "binary".to_string()],
            default: "json".to_string(),
        }
    }
}

/// Continuous cross-bundle batching tuning (`composer` subsystem).
///
/// When enabled, REFINE merges rows from every in-flight bundle (and
/// cascade segment) into shared engine steps instead of driving one
/// bundle at a time: rows retire as their segments finish and freshly
/// drafted bundles join at the next step boundary. Composition only
/// changes grouping — outputs stay bitwise-identical to the per-bundle
/// path (each row samples from its own `(run_seed, step, position)`
/// substream).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ComposerConfig {
    /// Compose steps across in-flight bundles (default off — the
    /// per-bundle REFINE path verbatim).
    pub enabled: bool,
    /// Row cap per composed engine dispatch; `0` (default) = uncapped,
    /// letting the engine tile oversized dispatches over its compiled
    /// batch sizes.
    pub max_rows: usize,
}

impl Default for ComposerConfig {
    fn default() -> Self {
        ComposerConfig { enabled: false, max_rows: 0 }
    }
}

/// Fault-tolerance tuning (`robustness` subsystem).
///
/// Governs the failure-side serving envelope: the engine-call watchdog,
/// the fleet health loop that resurrects quarantined replicas, the
/// coordinator's stage-poll cadence, and whether REFINE failures degrade
/// to the already-computed draft tokens instead of erroring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RobustnessConfig {
    /// Watchdog deadline on each engine call (ms). A reply that takes
    /// longer surfaces a typed `EngineTimeout`, which the fleet treats
    /// like a dead replica (quarantine + reroute). `0` (the default)
    /// disables the watchdog — calls block until the engine replies,
    /// the pre-robustness behaviour verbatim.
    pub call_timeout_ms: u64,
    /// Poll interval (ms) for the coordinator stage loops (admission,
    /// DRAFT, REFINE). Drain on shutdown completes within a small
    /// multiple of this (pinned by test).
    pub stage_poll_ms: u64,
    /// Serve the bundle's draft tokens (with `degraded: true` on the
    /// wire) when REFINE exhausts its reroutes, instead of erroring.
    pub draft_fallback: bool,
    /// Initial backoff (ms) before the health loop retries a replica
    /// respawn; doubles per consecutive failure.
    pub respawn_backoff_ms: u64,
    /// Upper bound on the respawn backoff (ms).
    pub respawn_backoff_cap_ms: u64,
    /// Circuit breaker: after this many *consecutive* failed respawn
    /// attempts the replica is retired permanently.
    pub max_respawns: u32,
}

impl Default for RobustnessConfig {
    fn default() -> Self {
        RobustnessConfig {
            call_timeout_ms: 0,
            stage_poll_ms: 50,
            draft_fallback: true,
            respawn_backoff_ms: 50,
            respawn_backoff_cap_ms: 5000,
            max_respawns: 5,
        }
    }
}

impl RobustnessConfig {
    /// The watchdog deadline as a `Duration`; `None` when disabled (0).
    pub fn call_timeout(&self) -> Option<std::time::Duration> {
        (self.call_timeout_ms > 0).then(|| std::time::Duration::from_millis(self.call_timeout_ms))
    }

    /// The coordinator stage-loop poll interval.
    pub fn stage_poll(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.stage_poll_ms)
    }
}

/// Cascade-refinement tuning (`cascade` subsystem).
///
/// The cascade splits a bundle's refinement into an ordered ladder of
/// resumable engine segments and can stop early when an intermediate
/// quality gate passes. Early exit only ever *saves* evaluations: the
/// sum of executed-segment NFEs never exceeds the unsplit schedule's
/// NFE, so the paper's guarantee floor is untouched.
#[derive(Debug, Clone, PartialEq)]
pub struct CascadeConfig {
    /// `off` (one uninterrupted segment — legacy behaviour, the default,
    /// byte-for-byte the pre-cascade wire output), `fixed` (run every
    /// ladder segment, no gates — bitwise-identical tokens to `off`), or
    /// `gated` (score the intermediate state between segments and exit
    /// early when the gate passes).
    pub mode: String,
    /// Interior segment boundaries in `(0, 1)`, strictly ascending. At
    /// planning time they snap to the bundle's step grid; boundaries at
    /// or below the bundle's run t0 are dropped, and the ladder always
    /// implicitly starts at the run t0 and ends at 1.
    pub ladder: Vec<f64>,
    /// Quality gate (`gated` mode only): a draft-quality proxy score of
    /// the intermediate state `>=` this exits the cascade early.
    pub gate_threshold: f64,
}

impl Default for CascadeConfig {
    fn default() -> Self {
        CascadeConfig { mode: "off".into(), ladder: vec![0.75, 0.9], gate_threshold: 0.45 }
    }
}

/// Engine-fleet tuning (`fleet` subsystem).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetConfig {
    /// Engine replicas to spawn — each its own engine thread + artifact
    /// cache, behind the deterministic least-loaded router. `1` (the
    /// default) is the single-engine behaviour verbatim.
    pub replicas: usize,
    /// REFINE-stage worker threads pulling from the staged channel (only
    /// used when `pipeline_depth >= 2`). More workers than healthy
    /// replicas just contend on the same execution streams, so size this
    /// to `replicas` in practice.
    pub refine_workers: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { replicas: 1, refine_workers: 1 }
    }
}

/// Adaptive warm-start controller tuning (`control` subsystem).
///
/// The controller picks a per-bundle `t0` from draft quality, clamped to
/// `[t0_min, t0_max]` so the paper's NFE guarantee keeps a hard floor:
/// no bundle ever pays more than `guaranteed_nfe(steps_cold, t0_min)`
/// evaluations in an adaptive mode.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlConfig {
    /// `static` (use the request's t0 verbatim — legacy behaviour),
    /// `prior` (t0 from the draft-model kind alone), or `scored`
    /// (t0 from proxy scores computed on the drafted batch).
    pub mode: String,
    /// Adaptive t0 floor: the guarantee budget is
    /// `guaranteed_nfe(steps_cold, t0_min)`.
    pub t0_min: f64,
    /// Adaptive t0 ceiling (best draft still gets ≥ 1 refinement step
    /// for any steps_cold since t0_max < 1).
    pub t0_max: f64,
    /// Discrete t0 choices; entries outside `[t0_min, t0_max]` are
    /// clamped at controller construction.
    pub grid: Vec<f64>,
    /// Optional calibration table `(min_score, t0)` learned by
    /// `wsfm selfcheck --calibrate`; highest matching `min_score` wins.
    /// Empty = map scores linearly onto the grid.
    pub calibration: Vec<(f64, f64)>,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            mode: "static".into(),
            t0_min: 0.35,
            t0_max: 0.95,
            grid: vec![0.35, 0.5, 0.65, 0.8, 0.9, 0.95],
            calibration: Vec::new(),
        }
    }
}

/// Dynamic batcher tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct BatcherConfig {
    /// Flush when this many samples are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long (µs).
    pub max_wait_us: u64,
}

/// Sampler defaults (overridable per request).
#[derive(Debug, Clone, PartialEq)]
pub struct SamplerConfig {
    /// Cold-run step count (the paper's NFE baseline, e.g. 20 or 1024).
    pub steps_cold: usize,
    /// Default warm-start time for WS requests.
    pub t0: f64,
    /// Update rule: "literal" (paper Fig. 3) or "exact".
    pub warp_mode: String,
}

impl Default for WsfmConfig {
    fn default() -> Self {
        WsfmConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            listen_addr: "127.0.0.1:7871".to_string(),
            batcher: BatcherConfig { max_batch: 32, max_wait_us: 2000 },
            sampler: SamplerConfig { steps_cold: 128, t0: 0.8, warp_mode: "literal".into() },
            queue_capacity: 256,
            pipeline_depth: 2,
            draft_workers: 1,
            seed: 0,
            control: ControlConfig::default(),
            fleet: FleetConfig::default(),
            cascade: CascadeConfig::default(),
            robustness: RobustnessConfig::default(),
            composer: ComposerConfig::default(),
            wire: WireConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl WsfmConfig {
    /// Load from a JSON file, layered over defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&json)
    }

    /// Layer a JSON object over defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = WsfmConfig::default();
        if let Some(s) = j.get("artifacts_dir").as_str() {
            c.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = j.get("listen_addr").as_str() {
            c.listen_addr = s.to_string();
        }
        if let Some(n) = j.get("queue_capacity").as_usize() {
            c.queue_capacity = n;
        }
        if let Some(n) = j.get("pipeline_depth").as_usize() {
            c.pipeline_depth = n;
        }
        if let Some(n) = j.get("draft_workers").as_usize() {
            c.draft_workers = n;
        }
        // Integer-preserving: the run seed feeds every RNG substream, so
        // values above 2^53 must not round through f64.
        if let Some(n) = j.get("seed").as_u64() {
            c.seed = n;
        }
        let b = j.get("batcher");
        if let Some(n) = b.get("max_batch").as_usize() {
            c.batcher.max_batch = n;
        }
        if let Some(n) = b.get("max_wait_us").as_f64() {
            c.batcher.max_wait_us = n as u64;
        }
        let s = j.get("sampler");
        if let Some(n) = s.get("steps_cold").as_usize() {
            c.sampler.steps_cold = n;
        }
        if let Some(n) = s.get("t0").as_f64() {
            c.sampler.t0 = n;
        }
        if let Some(m) = s.get("warp_mode").as_str() {
            c.sampler.warp_mode = m.to_string();
        }
        let f = j.get("fleet");
        if let Some(n) = f.get("replicas").as_usize() {
            c.fleet.replicas = n;
        }
        if let Some(n) = f.get("refine_workers").as_usize() {
            c.fleet.refine_workers = n;
        }
        let ctl = j.get("control");
        if let Some(m) = ctl.get("mode").as_str() {
            c.control.mode = m.to_string();
        }
        if let Some(n) = ctl.get("t0_min").as_f64() {
            c.control.t0_min = n;
        }
        if let Some(n) = ctl.get("t0_max").as_f64() {
            c.control.t0_max = n;
        }
        if let Some(arr) = ctl.get("grid").as_arr() {
            c.control.grid =
                arr.iter().filter_map(|v| v.as_f64()).collect();
        }
        if let Some(arr) = ctl.get("calibration").as_arr() {
            c.control.calibration = arr
                .iter()
                .filter_map(|e| {
                    Some((e.get("min_score").as_f64()?, e.get("t0").as_f64()?))
                })
                .collect();
        }
        let cas = j.get("cascade");
        if let Some(m) = cas.get("mode").as_str() {
            c.cascade.mode = m.to_string();
        }
        if let Some(arr) = cas.get("ladder").as_arr() {
            c.cascade.ladder = arr.iter().filter_map(|v| v.as_f64()).collect();
        }
        if let Some(n) = cas.get("gate_threshold").as_f64() {
            c.cascade.gate_threshold = n;
        }
        let rb = j.get("robustness");
        if let Some(n) = rb.get("call_timeout_ms").as_f64() {
            c.robustness.call_timeout_ms = n as u64;
        }
        if let Some(n) = rb.get("stage_poll_ms").as_f64() {
            c.robustness.stage_poll_ms = n as u64;
        }
        if let Some(b) = rb.get("draft_fallback").as_bool() {
            c.robustness.draft_fallback = b;
        }
        if let Some(n) = rb.get("respawn_backoff_ms").as_f64() {
            c.robustness.respawn_backoff_ms = n as u64;
        }
        if let Some(n) = rb.get("respawn_backoff_cap_ms").as_f64() {
            c.robustness.respawn_backoff_cap_ms = n as u64;
        }
        if let Some(n) = rb.get("max_respawns").as_usize() {
            c.robustness.max_respawns = n as u32;
        }
        let cp = j.get("composer");
        if let Some(b) = cp.get("enabled").as_bool() {
            c.composer.enabled = b;
        }
        if let Some(n) = cp.get("max_rows").as_usize() {
            c.composer.max_rows = n;
        }
        let w = j.get("wire");
        if let Some(arr) = w.get("codecs").as_arr() {
            c.wire.codecs =
                arr.iter().filter_map(|v| v.as_str().map(str::to_string)).collect();
        }
        if let Some(d) = w.get("default").as_str() {
            c.wire.default = d.to_string();
        }
        let o = j.get("obs");
        if let Some(b) = o.get("enabled").as_bool() {
            c.obs.enabled = b;
        }
        if let Some(n) = o.get("span_cap").as_usize() {
            c.obs.span_cap = n;
        }
        if let Some(n) = o.get("event_cap").as_usize() {
            c.obs.event_cap = n;
        }
        let l = o.get("ledger");
        if let Some(b) = l.get("enabled").as_bool() {
            c.obs.ledger.enabled = b;
        }
        if let Some(n) = l.get("cap").as_usize() {
            c.obs.ledger.cap = n;
        }
        if let Some(p) = l.get("path").as_str() {
            c.obs.ledger.path = p.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    /// Serialize (for `wsfm info` and test round-trips).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifacts_dir", Json::str(self.artifacts_dir.to_string_lossy().to_string())),
            ("listen_addr", Json::str(self.listen_addr.clone())),
            ("queue_capacity", Json::num(self.queue_capacity as f64)),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
            ("draft_workers", Json::num(self.draft_workers as f64)),
            ("seed", Json::num(self.seed as f64)),
            (
                "batcher",
                Json::obj(vec![
                    ("max_batch", Json::num(self.batcher.max_batch as f64)),
                    ("max_wait_us", Json::num(self.batcher.max_wait_us as f64)),
                ]),
            ),
            (
                "sampler",
                Json::obj(vec![
                    ("steps_cold", Json::num(self.sampler.steps_cold as f64)),
                    ("t0", Json::num(self.sampler.t0)),
                    ("warp_mode", Json::str(self.sampler.warp_mode.clone())),
                ]),
            ),
            (
                "fleet",
                Json::obj(vec![
                    ("replicas", Json::num(self.fleet.replicas as f64)),
                    ("refine_workers", Json::num(self.fleet.refine_workers as f64)),
                ]),
            ),
            (
                "cascade",
                Json::obj(vec![
                    ("mode", Json::str(self.cascade.mode.clone())),
                    ("ladder", Json::arr(self.cascade.ladder.iter().map(|&b| Json::num(b)))),
                    ("gate_threshold", Json::num(self.cascade.gate_threshold)),
                ]),
            ),
            (
                "robustness",
                Json::obj(vec![
                    ("call_timeout_ms", Json::num(self.robustness.call_timeout_ms as f64)),
                    ("stage_poll_ms", Json::num(self.robustness.stage_poll_ms as f64)),
                    ("draft_fallback", Json::Bool(self.robustness.draft_fallback)),
                    ("respawn_backoff_ms", Json::num(self.robustness.respawn_backoff_ms as f64)),
                    (
                        "respawn_backoff_cap_ms",
                        Json::num(self.robustness.respawn_backoff_cap_ms as f64),
                    ),
                    ("max_respawns", Json::num(self.robustness.max_respawns as f64)),
                ]),
            ),
            (
                "composer",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.composer.enabled)),
                    ("max_rows", Json::num(self.composer.max_rows as f64)),
                ]),
            ),
            (
                "wire",
                Json::obj(vec![
                    (
                        "codecs",
                        Json::arr(self.wire.codecs.iter().map(|c| Json::str(c.clone()))),
                    ),
                    ("default", Json::str(self.wire.default.clone())),
                ]),
            ),
            (
                "obs",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.obs.enabled)),
                    ("span_cap", Json::num(self.obs.span_cap as f64)),
                    ("event_cap", Json::num(self.obs.event_cap as f64)),
                    (
                        "ledger",
                        Json::obj(vec![
                            ("enabled", Json::Bool(self.obs.ledger.enabled)),
                            ("cap", Json::num(self.obs.ledger.cap as f64)),
                            ("path", Json::str(self.obs.ledger.path.clone())),
                        ]),
                    ),
                ]),
            ),
            (
                "control",
                Json::obj(vec![
                    ("mode", Json::str(self.control.mode.clone())),
                    ("t0_min", Json::num(self.control.t0_min)),
                    ("t0_max", Json::num(self.control.t0_max)),
                    ("grid", Json::arr(self.control.grid.iter().map(|&g| Json::num(g)))),
                    (
                        "calibration",
                        Json::arr(self.control.calibration.iter().map(|&(s, t)| {
                            Json::obj(vec![
                                ("min_score", Json::num(s)),
                                ("t0", Json::num(t)),
                            ])
                        })),
                    ),
                ]),
            ),
        ])
    }

    pub fn validate(&self) -> Result<()> {
        if self.batcher.max_batch == 0 {
            bail!("batcher.max_batch must be positive");
        }
        if self.queue_capacity == 0 {
            bail!("queue_capacity must be positive");
        }
        if self.pipeline_depth == 0 {
            bail!("pipeline_depth must be positive (1 = serial)");
        }
        if self.draft_workers == 0 {
            bail!("draft_workers must be positive");
        }
        if self.fleet.replicas == 0 {
            bail!("fleet.replicas must be positive (1 = single engine)");
        }
        if self.fleet.refine_workers == 0 {
            bail!("fleet.refine_workers must be positive");
        }
        if self.sampler.steps_cold == 0 {
            bail!("sampler.steps_cold must be positive");
        }
        if !(0.0..1.0).contains(&self.sampler.t0) {
            bail!("sampler.t0 must be in [0, 1), got {}", self.sampler.t0);
        }
        crate::core::schedule::WarpMode::parse(&self.sampler.warp_mode)?;
        crate::control::ControllerMode::parse(&self.control.mode)?;
        if !(0.0..1.0).contains(&self.control.t0_min)
            || !(0.0..1.0).contains(&self.control.t0_max)
            || self.control.t0_min > self.control.t0_max
        {
            bail!(
                "control: need 0 <= t0_min <= t0_max < 1, got [{}, {}]",
                self.control.t0_min,
                self.control.t0_max
            );
        }
        if self.control.grid.is_empty() {
            bail!("control.grid must be non-empty");
        }
        for &g in &self.control.grid {
            if !(0.0..1.0).contains(&g) {
                bail!("control.grid entry {g} outside [0, 1)");
            }
        }
        for &(s, t) in &self.control.calibration {
            if !s.is_finite() || !(0.0..1.0).contains(&t) {
                bail!("control.calibration entry (min_score={s}, t0={t}) invalid");
            }
        }
        crate::cascade::CascadeMode::parse(&self.cascade.mode)?;
        for &b in &self.cascade.ladder {
            if !b.is_finite() || !(0.0..1.0).contains(&b) || b == 0.0 {
                bail!("cascade.ladder entry {b} outside (0, 1)");
            }
        }
        // Entries are finite here, so >= is a sound strictness check.
        for w in self.cascade.ladder.windows(2) {
            if w[0] >= w[1] {
                bail!("cascade.ladder must be strictly ascending, got {:?}", self.cascade.ladder);
            }
        }
        if !self.cascade.gate_threshold.is_finite()
            || !(0.0..=1.0).contains(&self.cascade.gate_threshold)
        {
            bail!("cascade.gate_threshold must be in [0, 1], got {}", self.cascade.gate_threshold);
        }
        if self.robustness.stage_poll_ms == 0 {
            bail!("robustness.stage_poll_ms must be positive");
        }
        if self.robustness.respawn_backoff_ms == 0 {
            bail!("robustness.respawn_backoff_ms must be positive");
        }
        if self.robustness.respawn_backoff_cap_ms < self.robustness.respawn_backoff_ms {
            bail!(
                "robustness.respawn_backoff_cap_ms ({}) must be >= respawn_backoff_ms ({})",
                self.robustness.respawn_backoff_cap_ms,
                self.robustness.respawn_backoff_ms
            );
        }
        if self.robustness.max_respawns == 0 {
            bail!("robustness.max_respawns must be positive");
        }
        if self.wire.codecs.is_empty() {
            bail!("wire.codecs must be non-empty");
        }
        for name in &self.wire.codecs {
            if !crate::server::codec::SUPPORTED.contains(&name.as_str()) {
                bail!(
                    "wire.codecs entry {name:?} unknown (supported: {:?})",
                    crate::server::codec::SUPPORTED
                );
            }
        }
        if !self.wire.codecs.contains(&self.wire.default) {
            bail!(
                "wire.default {:?} must be one of wire.codecs {:?}",
                self.wire.default,
                self.wire.codecs
            );
        }
        if self.obs.span_cap == 0 {
            bail!("obs.span_cap must be positive");
        }
        if self.obs.event_cap == 0 {
            bail!("obs.event_cap must be positive");
        }
        if self.obs.ledger.cap == 0 {
            bail!("obs.ledger.cap must be positive");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        WsfmConfig::default().validate().unwrap();
    }

    #[test]
    fn json_layering() {
        let j = Json::parse(
            r#"{"listen_addr":"0.0.0.0:9000","batcher":{"max_batch":8},"sampler":{"t0":0.5},"pipeline_depth":6,"draft_workers":3}"#,
        )
        .unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.listen_addr, "0.0.0.0:9000");
        assert_eq!(c.batcher.max_batch, 8);
        assert_eq!(c.sampler.t0, 0.5);
        assert_eq!(c.pipeline_depth, 6);
        assert_eq!(c.draft_workers, 3);
        // Untouched fields keep defaults.
        assert_eq!(c.queue_capacity, WsfmConfig::default().queue_capacity);
    }

    #[test]
    fn control_section_layering() {
        let j = Json::parse(
            r#"{"control":{"mode":"scored","t0_min":0.2,"t0_max":0.9,"grid":[0.2,0.5,0.9],"calibration":[{"min_score":0.7,"t0":0.9},{"min_score":0.0,"t0":0.2}]}}"#,
        )
        .unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.control.mode, "scored");
        assert_eq!(c.control.t0_min, 0.2);
        assert_eq!(c.control.t0_max, 0.9);
        assert_eq!(c.control.grid, vec![0.2, 0.5, 0.9]);
        assert_eq!(c.control.calibration, vec![(0.7, 0.9), (0.0, 0.2)]);
        // Untouched -> defaults (static mode, paper grid).
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.control, ControlConfig::default());
        assert_eq!(d.control.mode, "static");
    }

    #[test]
    fn fleet_section_layering() {
        let j = Json::parse(r#"{"fleet":{"replicas":4,"refine_workers":2}}"#).unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.fleet.replicas, 4);
        assert_eq!(c.fleet.refine_workers, 2);
        // Untouched -> defaults: 1 replica = single-engine behaviour.
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.fleet, FleetConfig::default());
        assert_eq!(d.fleet.replicas, 1);
        assert_eq!(d.fleet.refine_workers, 1);
    }

    #[test]
    fn cascade_section_layering() {
        let j = Json::parse(
            r#"{"cascade":{"mode":"gated","ladder":[0.6,0.8,0.95],"gate_threshold":0.3}}"#,
        )
        .unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.cascade.mode, "gated");
        assert_eq!(c.cascade.ladder, vec![0.6, 0.8, 0.95]);
        assert_eq!(c.cascade.gate_threshold, 0.3);
        // Untouched -> defaults: cascade off = legacy single-segment path.
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.cascade, CascadeConfig::default());
        assert_eq!(d.cascade.mode, "off");
        // An empty ladder is a degenerate-but-valid single-segment cascade.
        let e = Json::parse(r#"{"cascade":{"mode":"fixed","ladder":[]}}"#).unwrap();
        assert!(WsfmConfig::from_json(&e).unwrap().cascade.ladder.is_empty());
    }

    #[test]
    fn robustness_section_layering() {
        let j = Json::parse(
            r#"{"robustness":{"call_timeout_ms":2000,"stage_poll_ms":10,"draft_fallback":false,"respawn_backoff_ms":25,"respawn_backoff_cap_ms":400,"max_respawns":3}}"#,
        )
        .unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.robustness.call_timeout_ms, 2000);
        assert_eq!(c.robustness.stage_poll_ms, 10);
        assert!(!c.robustness.draft_fallback);
        assert_eq!(c.robustness.respawn_backoff_ms, 25);
        assert_eq!(c.robustness.respawn_backoff_cap_ms, 400);
        assert_eq!(c.robustness.max_respawns, 3);
        // Untouched -> defaults: watchdog off, fallback on.
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.robustness, RobustnessConfig::default());
        assert_eq!(d.robustness.call_timeout_ms, 0);
        assert!(d.robustness.draft_fallback);
    }

    #[test]
    fn composer_section_layering() {
        let j = Json::parse(r#"{"composer":{"enabled":true,"max_rows":64}}"#).unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert!(c.composer.enabled);
        assert_eq!(c.composer.max_rows, 64);
        // Untouched -> defaults: composer off = per-bundle REFINE path.
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.composer, ComposerConfig::default());
        assert!(!d.composer.enabled);
        assert_eq!(d.composer.max_rows, 0);
    }

    #[test]
    fn wire_section_layering() {
        let j = Json::parse(r#"{"wire":{"codecs":["binary"],"default":"binary"}}"#).unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.wire.codecs, vec!["binary"]);
        assert_eq!(c.wire.default, "binary");
        // Untouched -> defaults: both codecs offered, json (legacy) first.
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.wire, WireConfig::default());
        assert_eq!(d.wire.default, "json");
        assert_eq!(d.wire.codecs, vec!["json", "binary"]);
    }

    #[test]
    fn obs_section_layering() {
        let j = Json::parse(
            r#"{"obs":{"enabled":false,"span_cap":64,"event_cap":16,"ledger":{"enabled":false,"cap":32,"path":"/tmp/wsfm.ledger"}}}"#,
        )
        .unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.span_cap, 64);
        assert_eq!(c.obs.event_cap, 16);
        assert!(!c.obs.ledger.enabled);
        assert_eq!(c.obs.ledger.cap, 32);
        assert_eq!(c.obs.ledger.path, "/tmp/wsfm.ledger");
        // Untouched -> defaults: journals on, bounded caps, ledger on
        // in-memory (no sink).
        let d = WsfmConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.obs, ObsConfig::default());
        assert!(d.obs.enabled);
        assert_eq!(d.obs.span_cap, 4096);
        assert_eq!(d.obs.event_cap, 1024);
        assert!(d.obs.ledger.enabled);
        assert_eq!(d.obs.ledger.cap, 1024);
        assert!(d.obs.ledger.path.is_empty());
        // Ledger fields layer independently of the obs gate.
        let e = Json::parse(r#"{"obs":{"ledger":{"cap":8}}}"#).unwrap();
        let c = WsfmConfig::from_json(&e).unwrap();
        assert!(c.obs.enabled && c.obs.ledger.enabled);
        assert_eq!(c.obs.ledger.cap, 8);
    }

    #[test]
    fn config_seed_is_exact_above_2_53() {
        let j = Json::parse(&format!("{{\"seed\":{}}}", u64::MAX)).unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.seed, u64::MAX);
    }

    #[test]
    fn invalid_rejected() {
        for bad in [
            r#"{"wire":{"codecs":[]}}"#,
            r#"{"wire":{"codecs":["zstd"]}}"#,
            r#"{"wire":{"codecs":["binary"],"default":"json"}}"#,
            r#"{"wire":{"default":"zstd"}}"#,
            r#"{"batcher":{"max_batch":0}}"#,
            r#"{"sampler":{"t0":1.5}}"#,
            r#"{"sampler":{"warp_mode":"sideways"}}"#,
            r#"{"pipeline_depth":0}"#,
            r#"{"draft_workers":0}"#,
            r#"{"fleet":{"replicas":0}}"#,
            r#"{"fleet":{"refine_workers":0}}"#,
            r#"{"control":{"mode":"psychic"}}"#,
            r#"{"control":{"t0_min":0.9,"t0_max":0.5}}"#,
            r#"{"control":{"t0_max":1.0}}"#,
            r#"{"control":{"grid":[]}}"#,
            r#"{"control":{"grid":[0.5,1.2]}}"#,
            r#"{"control":{"calibration":[{"min_score":0.5,"t0":1.5}]}}"#,
            r#"{"cascade":{"mode":"sideways"}}"#,
            r#"{"cascade":{"ladder":[0.9,0.6]}}"#,
            r#"{"cascade":{"ladder":[0.5,0.5]}}"#,
            r#"{"cascade":{"ladder":[0.0,0.5]}}"#,
            r#"{"cascade":{"ladder":[0.5,1.0]}}"#,
            r#"{"cascade":{"gate_threshold":1.5}}"#,
            r#"{"robustness":{"stage_poll_ms":0}}"#,
            r#"{"robustness":{"respawn_backoff_ms":0}}"#,
            r#"{"robustness":{"respawn_backoff_cap_ms":10}}"#,
            r#"{"robustness":{"max_respawns":0}}"#,
            r#"{"obs":{"span_cap":0}}"#,
            r#"{"obs":{"event_cap":0}}"#,
            r#"{"obs":{"ledger":{"cap":0}}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(WsfmConfig::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = WsfmConfig::default();
        let j = c.to_json();
        let c2 = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn from_file_missing_errors() {
        assert!(WsfmConfig::from_file(Path::new("/nonexistent/wsfm.json")).is_err());
    }
}
