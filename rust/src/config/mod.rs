//! Typed configuration for the serving stack.
//!
//! Defaults ← JSON config file (`--config path`) ← CLI overrides, in that
//! precedence order. The config is deliberately explicit: everything the
//! coordinator, batcher, and sampler consult lives here, and `validate()`
//! rejects inconsistent settings at startup rather than mid-request.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Top-level configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WsfmConfig {
    /// Directory containing the AOT artifacts + manifest.
    pub artifacts_dir: PathBuf,
    /// TCP listen address for `wsfm serve`.
    pub listen_addr: String,
    pub batcher: BatcherConfig,
    pub sampler: SamplerConfig,
    /// Bounded admission queue size (backpressure beyond this).
    pub queue_capacity: usize,
    /// Max bundles in flight across the DRAFT→REFINE pipeline. `1` runs
    /// the legacy serial path (admission thread executes bundles inline);
    /// `>= 2` lets drafting bundle N+1 overlap refining bundle N.
    pub pipeline_depth: usize,
    /// DRAFT-stage worker threads (only used when `pipeline_depth >= 2`).
    pub draft_workers: usize,
    /// Global RNG seed (per-bundle substreams are derived from it).
    pub seed: u64,
}

/// Dynamic batcher tuning.
#[derive(Debug, Clone, PartialEq)]
pub struct BatcherConfig {
    /// Flush when this many samples are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long (µs).
    pub max_wait_us: u64,
}

/// Sampler defaults (overridable per request).
#[derive(Debug, Clone, PartialEq)]
pub struct SamplerConfig {
    /// Cold-run step count (the paper's NFE baseline, e.g. 20 or 1024).
    pub steps_cold: usize,
    /// Default warm-start time for WS requests.
    pub t0: f64,
    /// Update rule: "literal" (paper Fig. 3) or "exact".
    pub warp_mode: String,
}

impl Default for WsfmConfig {
    fn default() -> Self {
        WsfmConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            listen_addr: "127.0.0.1:7871".to_string(),
            batcher: BatcherConfig { max_batch: 32, max_wait_us: 2000 },
            sampler: SamplerConfig { steps_cold: 128, t0: 0.8, warp_mode: "literal".into() },
            queue_capacity: 256,
            pipeline_depth: 2,
            draft_workers: 1,
            seed: 0,
        }
    }
}

impl WsfmConfig {
    /// Load from a JSON file, layered over defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&json)
    }

    /// Layer a JSON object over defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = WsfmConfig::default();
        if let Some(s) = j.get("artifacts_dir").as_str() {
            c.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = j.get("listen_addr").as_str() {
            c.listen_addr = s.to_string();
        }
        if let Some(n) = j.get("queue_capacity").as_usize() {
            c.queue_capacity = n;
        }
        if let Some(n) = j.get("pipeline_depth").as_usize() {
            c.pipeline_depth = n;
        }
        if let Some(n) = j.get("draft_workers").as_usize() {
            c.draft_workers = n;
        }
        if let Some(n) = j.get("seed").as_f64() {
            c.seed = n as u64;
        }
        let b = j.get("batcher");
        if let Some(n) = b.get("max_batch").as_usize() {
            c.batcher.max_batch = n;
        }
        if let Some(n) = b.get("max_wait_us").as_f64() {
            c.batcher.max_wait_us = n as u64;
        }
        let s = j.get("sampler");
        if let Some(n) = s.get("steps_cold").as_usize() {
            c.sampler.steps_cold = n;
        }
        if let Some(n) = s.get("t0").as_f64() {
            c.sampler.t0 = n;
        }
        if let Some(m) = s.get("warp_mode").as_str() {
            c.sampler.warp_mode = m.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    /// Serialize (for `wsfm info` and test round-trips).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifacts_dir", Json::str(self.artifacts_dir.to_string_lossy().to_string())),
            ("listen_addr", Json::str(self.listen_addr.clone())),
            ("queue_capacity", Json::num(self.queue_capacity as f64)),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
            ("draft_workers", Json::num(self.draft_workers as f64)),
            ("seed", Json::num(self.seed as f64)),
            (
                "batcher",
                Json::obj(vec![
                    ("max_batch", Json::num(self.batcher.max_batch as f64)),
                    ("max_wait_us", Json::num(self.batcher.max_wait_us as f64)),
                ]),
            ),
            (
                "sampler",
                Json::obj(vec![
                    ("steps_cold", Json::num(self.sampler.steps_cold as f64)),
                    ("t0", Json::num(self.sampler.t0)),
                    ("warp_mode", Json::str(self.sampler.warp_mode.clone())),
                ]),
            ),
        ])
    }

    pub fn validate(&self) -> Result<()> {
        if self.batcher.max_batch == 0 {
            bail!("batcher.max_batch must be positive");
        }
        if self.queue_capacity == 0 {
            bail!("queue_capacity must be positive");
        }
        if self.pipeline_depth == 0 {
            bail!("pipeline_depth must be positive (1 = serial)");
        }
        if self.draft_workers == 0 {
            bail!("draft_workers must be positive");
        }
        if self.sampler.steps_cold == 0 {
            bail!("sampler.steps_cold must be positive");
        }
        if !(0.0..1.0).contains(&self.sampler.t0) {
            bail!("sampler.t0 must be in [0, 1), got {}", self.sampler.t0);
        }
        crate::core::schedule::WarpMode::parse(&self.sampler.warp_mode)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        WsfmConfig::default().validate().unwrap();
    }

    #[test]
    fn json_layering() {
        let j = Json::parse(
            r#"{"listen_addr":"0.0.0.0:9000","batcher":{"max_batch":8},"sampler":{"t0":0.5},"pipeline_depth":6,"draft_workers":3}"#,
        )
        .unwrap();
        let c = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c.listen_addr, "0.0.0.0:9000");
        assert_eq!(c.batcher.max_batch, 8);
        assert_eq!(c.sampler.t0, 0.5);
        assert_eq!(c.pipeline_depth, 6);
        assert_eq!(c.draft_workers, 3);
        // Untouched fields keep defaults.
        assert_eq!(c.queue_capacity, WsfmConfig::default().queue_capacity);
    }

    #[test]
    fn invalid_rejected() {
        for bad in [
            r#"{"batcher":{"max_batch":0}}"#,
            r#"{"sampler":{"t0":1.5}}"#,
            r#"{"sampler":{"warp_mode":"sideways"}}"#,
            r#"{"pipeline_depth":0}"#,
            r#"{"draft_workers":0}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(WsfmConfig::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = WsfmConfig::default();
        let j = c.to_json();
        let c2 = WsfmConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn from_file_missing_errors() {
        assert!(WsfmConfig::from_file(Path::new("/nonexistent/wsfm.json")).is_err());
    }
}
