//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf) + update-rule ablation.
//!
//! Measures the L3 components around the PJRT engine call — categorical
//! sampling (scalar, substream-sequential, and row-parallel), the sampling
//! loop's channel round-trip cost (per-step vs engine-resident), batcher
//! offer/flush, queue handoff, JSON protocol encode/decode, the serving
//! coordinator's serial-vs-pipelined bundle throughput, the executor
//! fleet's replica scaling (replicas=1 vs 4 on a flat-cost stage mock),
//! the step-level batch composer (per-bundle vs composed refinement on a
//! flat per-call-cost mock), the watchdog-guarded vs bare engine-call
//! reply wait, the obs tracing layer off vs on — and the engine
//! step itself per domain/batch, so the "coordinator must not be the
//! bottleneck" target is quantified.
//!
//! Results additionally land in `BENCH_hotpath.json` (benchmark name →
//! mean ns/iter) so the perf trajectory is tracked across PRs.
//!
//! `cargo bench --bench hotpath`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};
use wsfm::config::WsfmConfig;
use wsfm::coordinator::batcher::{Batcher, FlushPolicy};
use wsfm::coordinator::request::{DraftSpec, GenRequest, GenResponse};
use wsfm::coordinator::Service;
use wsfm::core::prob;
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::{guaranteed_nfe, WarpMode};
use wsfm::core::tensor::TokenBatch;
use wsfm::core::workers::WorkerPool;
use wsfm::fleet::FleetHandle;
use wsfm::harness::common::Env;
use wsfm::runtime::{ArtifactMeta, Executor, LoopReport, LoopScratch, LoopSpec, TensorSpec};
use wsfm::sampler::{sample_warm, sample_warm_stepwise, SamplerParams};
use wsfm::server::{Binary, Codec, JsonLines, WireResponse};
use wsfm::util::bench::{black_box, Bench, BenchStats};
use wsfm::util::json::Json;

/// Accumulate a finished benchmark into the machine-readable results.
fn rec(results: &mut Vec<(String, f64)>, stats: BenchStats) {
    results.push((stats.name.clone(), stats.mean_ns()));
}

fn bench_l3_components(results: &mut Vec<(String, f64)>) {
    let b = Bench::default();

    // 1. Categorical sampling over a [32, 64, 27] probs tensor — the only
    //    per-token L3 work per Euler step. Scalar baseline first.
    let mut rng = Pcg64::new(0);
    let vocab = 27;
    let rows = 32 * 64;
    let probs: Vec<f32> = (0..rows * vocab).map(|_| rng.uniform_f32() + 0.01).collect();
    let mut out = vec![0i32; rows];
    rec(results, b.run("categorical_batch 32x64x27", || {
        prob::categorical_batch(black_box(&probs), vocab, &mut out, &mut rng);
    }));

    // Larger image-shaped tensor: scalar vs substream vs parallel. The
    // substream path (one stateless Pcg64 per row) is the determinism
    // contract that makes the parallel path bitwise-reproducible.
    let vocab2 = 32;
    let rows2 = 16 * 256;
    let probs2: Vec<f32> = (0..rows2 * vocab2).map(|_| rng.uniform_f32() + 0.01).collect();
    let mut out2 = vec![0i32; rows2];
    rec(results, b.run("categorical_batch 16x256x32", || {
        prob::categorical_batch(black_box(&probs2), vocab2, &mut out2, &mut rng);
    }));
    let mut step = 0u64;
    rec(results, b.run("categorical_batch_seeded 16x256x32", || {
        prob::categorical_batch_seeded(black_box(&probs2), vocab2, &mut out2, 42, step);
        step += 1;
    }));
    let single = WorkerPool::new(1);
    rec(results, b.run("categorical_batch_par 16x256x32 t1", || {
        prob::categorical_batch_par(black_box(&probs2), vocab2, &mut out2, 42, step, &single);
        step += 1;
    }));
    let pool = WorkerPool::shared();
    // "shared-tN" keeps the key distinct from the t1 baseline even when
    // the machine (or WSFM_WORKERS) only offers one worker.
    rec(results, b.run(&format!("categorical_batch_par 16x256x32 shared-t{}", pool.threads()), || {
        prob::categorical_batch_par(black_box(&probs2), vocab2, &mut out2, 42, step, pool);
        step += 1;
    }));

    // 2. Batcher offer+flush cycle.
    let mk_req = |i: u64| GenRequest {
        id: i,
        domain: "text8".into(),
        tag: "ws_t080".into(),
        draft: DraftSpec::Lstm,
        n_samples: 1,
        t0: 0.8,
        steps_cold: 128,
        warp_mode: WarpMode::Literal,
        seed: i,
        timing: false,
        submitted: Instant::now(),
    };
    rec(results, b.run("batcher offer x32 + flush", || {
        let mut batcher =
            Batcher::new(FlushPolicy { max_batch: 32, max_wait: std::time::Duration::from_secs(1) });
        for i in 0..32 {
            if let Some(bundle) = batcher.offer(mk_req(i)) {
                black_box(bundle.total_samples());
            }
        }
        black_box(batcher.flush_all().len());
    }));

    // 3. Wire protocol encode/decode.
    let line = r#"{"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm","n_samples":4,"t0":0.8,"steps":1024,"seed":7,"decode":true}"#;
    rec(results, b.run("protocol parse_request", || {
        black_box(wsfm::server::protocol::parse_request(black_box(line)).unwrap());
    }));

    // 4. RNG noise fill (draft-model input generation, 32x64x27 gumbel).
    let mut noise = vec![0.0f32; 32 * 64 * 27];
    rec(results, b.run("gumbel fill 32x64x27", || {
        rng.fill_gumbel_f32(&mut noise);
        black_box(noise[0]);
    }));
}

// ---------------------------------------------------------------------------
// Wire codecs: json lines vs length-prefixed binary frames
// ---------------------------------------------------------------------------

/// Price the framing itself (EXPERIMENTS.md §Wire): one Generate
/// response carrying 8 rows × 1k tokens, encoded to a buffer and decoded
/// back per codec. The JSON wire renders every token as decimal text;
/// the binary wire writes `i32` LE words behind a length prefix — these
/// rows quantify that gap on the payload shape the serving path ships.
fn bench_wire_codecs(results: &mut Vec<(String, f64)>) {
    let b = Bench::default();
    let resp = WireResponse::Generate {
        resp: GenResponse {
            id: 7,
            samples: (0..8)
                .map(|r| (0..1000).map(|i| ((r * 1000 + i) % 27) as i32).collect())
                .collect(),
            nfe: 205,
            t0_used: 0.8,
            cascade: None,
            queue_wait: Duration::from_micros(120),
            draft_time: Duration::from_micros(800),
            refine_time: Duration::from_micros(2600),
            total_time: Duration::from_micros(3520),
            degraded: None,
            timing: None,
        },
        texts: None,
    };
    let codecs: [(&str, Box<dyn Codec>); 2] =
        [("json", Box::new(JsonLines)), ("binary", Box::new(Binary))];
    for (name, mut codec) in codecs {
        let mut buf: Vec<u8> = Vec::new();
        rec(results, b.run(&format!("wire encode {name} 8x1k"), || {
            buf.clear();
            codec.write_response(&mut buf, black_box(&resp)).unwrap();
            black_box(buf.len());
        }));
        rec(results, b.run(&format!("wire decode {name} 8x1k"), || {
            let mut slice: &[u8] = black_box(&buf);
            black_box(codec.read_response(&mut slice).unwrap());
        }));
    }
}

// ---------------------------------------------------------------------------
// Sampling-loop round-trip cost (mock executor, no artifacts needed)
// ---------------------------------------------------------------------------

/// Analytic drift denoiser used to isolate loop/coordination overhead.
struct LoopMock {
    batch: usize,
    seq_len: usize,
    vocab: usize,
    calls: AtomicUsize,
}

impl LoopMock {
    fn new(batch: usize, seq_len: usize, vocab: usize) -> Self {
        LoopMock { batch, seq_len, vocab, calls: AtomicUsize::new(0) }
    }
}

impl Executor for LoopMock {
    fn step_into(
        &self,
        _a: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let coef = (h * warp / (1.0 - t).max(1e-6)).min(1.0);
        out.clear();
        out.reserve(tokens.len() * self.vocab);
        let base = coef / self.vocab as f32;
        for &tok in tokens {
            for j in 0..self.vocab {
                let stay = if j as i32 == tok { 1.0 - coef } else { 0.0 };
                out.push(stay + base);
            }
        }
        Ok(())
    }

    fn draft(&self, _a: &str, _n: &[f32]) -> anyhow::Result<Vec<i32>> {
        anyhow::bail!("no drafts")
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        Ok(ArtifactMeta {
            name: artifact.to_string(),
            hlo_file: String::new(),
            domain: "mock".into(),
            kind: "step".into(),
            tag: "cold".into(),
            draft: None,
            batch: self.batch,
            seq_len: self.seq_len,
            vocab: self.vocab,
            t0: Some(0.0),
            latent_dim: None,
            inputs: vec![TensorSpec {
                name: "x_t".into(),
                shape: vec![self.batch, self.seq_len],
                dtype: "s32".into(),
            }],
            outputs: vec![TensorSpec {
                name: "probs".into(),
                shape: vec![self.batch, self.seq_len, self.vocab],
                dtype: "f32".into(),
            }],
            content_hash: None,
        })
    }
}

/// A [`LoopMock`] behind a dedicated thread + mpsc channel — the same
/// shape as the production engine thread, so the difference between the
/// per-step path (`sample_warm_stepwise`: one round-trip *per Euler step*,
/// plus a tokens copy and a fresh probs vec each crossing) and the
/// engine-resident path (`sample_warm` via `run_loop`: one round-trip per
/// *run*) is exactly the overhead the tentpole removes.
enum WireReq {
    Step { tokens: Vec<i32>, t: f32, h: f32, warp: f32, resp: mpsc::Sender<anyhow::Result<Vec<f32>>> },
    Loop { spec: LoopSpec, tokens: Vec<i32>, resp: mpsc::Sender<anyhow::Result<(Vec<i32>, LoopReport)>> },
    Stop,
}

struct ChannelExec {
    tx: mpsc::Sender<WireReq>,
    batch: usize,
    seq_len: usize,
    vocab: usize,
}

impl ChannelExec {
    fn spawn(batch: usize, seq_len: usize, vocab: usize) -> ChannelExec {
        let (tx, rx) = mpsc::channel::<WireReq>();
        std::thread::spawn(move || {
            let mock = LoopMock::new(batch, seq_len, vocab);
            let mut scratch = LoopScratch::default();
            while let Ok(req) = rx.recv() {
                match req {
                    WireReq::Step { tokens, t, h, warp, resp } => {
                        let _ = resp.send(mock.step("m", &tokens, t, h, warp));
                    }
                    WireReq::Loop { spec, mut tokens, resp } => {
                        let r = mock
                            .run_loop(&spec, &mut tokens, &mut scratch)
                            .map(|rep| (tokens, rep));
                        let _ = resp.send(r);
                    }
                    WireReq::Stop => break,
                }
            }
        });
        ChannelExec { tx, batch, seq_len, vocab }
    }

    fn stop(&self) {
        let _ = self.tx.send(WireReq::Stop);
    }
}

impl Executor for ChannelExec {
    fn step(&self, _a: &str, tokens: &[i32], t: f32, h: f32, warp: f32) -> anyhow::Result<Vec<f32>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(WireReq::Step { tokens: tokens.to_vec(), t, h, warp, resp })
            .map_err(|_| anyhow::anyhow!("bench engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("bench engine thread gone"))?
    }

    fn draft(&self, _a: &str, _n: &[f32]) -> anyhow::Result<Vec<i32>> {
        anyhow::bail!("no drafts")
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        LoopMock::new(self.batch, self.seq_len, self.vocab).meta(artifact)
    }

    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        _scratch: &mut LoopScratch,
    ) -> anyhow::Result<LoopReport> {
        let (resp, rx) = mpsc::channel();
        let staged = std::mem::take(tokens);
        self.tx
            .send(WireReq::Loop { spec: spec.clone(), tokens: staged, resp })
            .map_err(|_| anyhow::anyhow!("bench engine thread gone"))?;
        let (final_tokens, report) =
            rx.recv().map_err(|_| anyhow::anyhow!("bench engine thread gone"))??;
        *tokens = final_tokens;
        Ok(report)
    }
}

fn bench_loop_roundtrip(results: &mut Vec<(String, f64)>) {
    let b = Bench::quick();
    let (batch, seq_len, vocab, steps) = (8usize, 64usize, 27usize, 32usize);
    let params = SamplerParams {
        artifact: "m".into(),
        steps_cold: steps,
        t0: 0.0,
        warp_mode: WarpMode::Exact,
    };

    // In-process: loop-body cost without any channel (upper bound).
    let mock = LoopMock::new(batch, seq_len, vocab);
    let mut rng = Pcg64::new(1);
    rec(results, b.run(&format!("loop in-proc stepwise {steps}x {batch}x{seq_len}x{vocab}"), || {
        let out =
            sample_warm_stepwise(&mock, &params, TokenBatch::zeros(batch, seq_len), &mut rng, false)
                .unwrap();
        black_box(out.nfe);
    }));
    rec(results, b.run(&format!("loop in-proc resident {steps}x {batch}x{seq_len}x{vocab}"), || {
        let out = sample_warm(&mock, &params, TokenBatch::zeros(batch, seq_len), &mut rng, false)
            .unwrap();
        black_box(out.nfe);
    }));

    // Cross-thread: the production shape. Stepwise pays `steps` channel
    // round-trips + copies; resident pays exactly one.
    let chan = ChannelExec::spawn(batch, seq_len, vocab);
    rec(results, b.run(&format!("loop x-thread per-step {steps}x {batch}x{seq_len}x{vocab}"), || {
        let out =
            sample_warm_stepwise(&chan, &params, TokenBatch::zeros(batch, seq_len), &mut rng, false)
                .unwrap();
        black_box(out.nfe);
    }));
    rec(results, b.run(&format!("loop x-thread resident {steps}x {batch}x{seq_len}x{vocab}"), || {
        let out = sample_warm(&chan, &params, TokenBatch::zeros(batch, seq_len), &mut rng, false)
            .unwrap();
        black_box(out.nfe);
    }));
    chan.stop();
}

// ---------------------------------------------------------------------------
// Serial vs pipelined coordinator throughput (mock executor)
// ---------------------------------------------------------------------------

/// Executor with explicit, flat stage costs: `draft()` sleeps
/// `draft_cost`, `run_loop()` sleeps `refine_cost`. Isolates the
/// coordinator's pipelining win — with depth 1 each bundle pays
/// draft + refine serially; pipelined, drafting bundle N+1 hides behind
/// refining bundle N, so per-bundle cost approaches max(draft, refine).
struct StageCostExec {
    batch: usize,
    seq_len: usize,
    vocab: usize,
    draft_cost: Duration,
    refine_cost: Duration,
}

impl Executor for StageCostExec {
    fn step(&self, _a: &str, _t: &[i32], _time: f32, _h: f32, _w: f32) -> anyhow::Result<Vec<f32>> {
        anyhow::bail!("run_loop is overridden; per-step path unused")
    }

    fn draft(&self, _a: &str, _noise: &[f32]) -> anyhow::Result<Vec<i32>> {
        std::thread::sleep(self.draft_cost);
        Ok(vec![0; self.batch * self.seq_len])
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        let is_draft = artifact.contains("draft");
        Ok(ArtifactMeta {
            name: artifact.to_string(),
            hlo_file: String::new(),
            domain: "mock".into(),
            kind: if is_draft { "draft".into() } else { "step".into() },
            tag: "cold".into(),
            draft: is_draft.then(|| "lstm".to_string()),
            batch: self.batch,
            seq_len: self.seq_len,
            vocab: self.vocab,
            t0: Some(0.0),
            latent_dim: None,
            inputs: vec![TensorSpec {
                name: if is_draft { "noise".into() } else { "x_t".into() },
                shape: vec![self.batch, self.seq_len],
                dtype: if is_draft { "f32".into() } else { "s32".into() },
            }],
            outputs: vec![],
            content_hash: None,
        })
    }

    fn run_loop(
        &self,
        spec: &LoopSpec,
        tokens: &mut Vec<i32>,
        _scratch: &mut LoopScratch,
    ) -> anyhow::Result<LoopReport> {
        let start = Instant::now();
        // `refine_cost` is the price of a FULL run; a cascade segment
        // pays its NFE share, so early exits genuinely save wall-clock
        // in the serve bench (full specs sleep exactly refine_cost).
        let schedule = wsfm::core::schedule::Schedule::segment(
            spec.steps_cold,
            spec.t0,
            spec.t_start,
            spec.t_end,
        )?;
        let full = guaranteed_nfe(spec.steps_cold, spec.t0).max(1);
        std::thread::sleep(self.refine_cost.mul_f64(schedule.nfe() as f64 / full as f64));
        tokens.fill(1);
        Ok(LoopReport { nfe: schedule.nfe(), elapsed: start.elapsed(), snapshots: None })
    }
}

fn stage_cost_manifest(batch: usize, seq_len: usize, vocab: usize) -> wsfm::runtime::Manifest {
    let meta = |name: &str, kind: &str, draft: Option<&str>| ArtifactMeta {
        name: name.to_string(),
        hlo_file: String::new(),
        domain: "mock".into(),
        kind: kind.into(),
        tag: "cold".into(),
        draft: draft.map(|d| d.to_string()),
        batch,
        seq_len,
        vocab,
        t0: Some(0.0),
        latent_dim: None,
        inputs: vec![TensorSpec {
            name: "in".into(),
            shape: vec![batch, seq_len],
            dtype: "f32".into(),
        }],
        outputs: vec![],
        content_hash: None,
    };
    wsfm::runtime::Manifest {
        dir: std::path::PathBuf::from("/tmp"),
        artifacts: vec![
            meta("mock_cold_step_b8", "step", None),
            meta("mock_draft_lstm_b8", "draft", Some("lstm")),
        ],
        domains: wsfm::util::json::Json::Null,
        batch_sizes: std::collections::BTreeMap::new(),
        schema_version: 1,
    }
}

/// Shared serve-bench shape for the coordinator/fleet throughput rows.
const SERVE_BENCH_SHAPE: (usize, usize, usize) = (8, 32, 16);

/// Shared harness for the serve-throughput benches: start a [`Service`]
/// over `exec` + the stage-cost manifest, warm the stage threads with one
/// request, then time `n_requests` full-bundle (size-flushed) requests
/// end-to-end. Returns mean ns/bundle. Keeping one harness guarantees the
/// serial-vs-pipelined and replicas=1-vs-4 rows stay methodologically
/// comparable.
fn run_serve_bench<E: Executor + 'static>(exec: E, mut cfg: WsfmConfig, n_requests: u64) -> f64 {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    let request = |seed: u64| GenRequest {
        id: 0,
        domain: "mock".into(),
        tag: "cold".into(),
        draft: DraftSpec::Lstm,
        n_samples: batch, // one full bundle per request (size flush)
        t0: 0.5,
        steps_cold: 10,
        warp_mode: WarpMode::Exact,
        seed,
        timing: false,
        submitted: Instant::now(),
    };
    cfg.batcher.max_batch = batch;
    let svc = Service::start(exec, stage_cost_manifest(batch, seq_len, vocab), cfg);
    svc.generate(request(0)).unwrap(); // warm the stage threads
    let start = Instant::now();
    let rxs: Vec<_> = (1..=n_requests).map(|i| svc.submit(request(i)).unwrap()).collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let per_bundle = start.elapsed().as_nanos() as f64 / n_requests as f64;
    svc.shutdown();
    per_bundle
}

fn bench_pipeline_throughput(results: &mut Vec<(String, f64)>) {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    for (label, depth, workers) in
        [("serve bundle serial depth=1", 1, 1), ("serve bundle pipelined depth=4 dw=2", 4, 2)]
    {
        let exec = StageCostExec {
            batch,
            seq_len,
            vocab,
            draft_cost: Duration::from_micros(200),
            refine_cost: Duration::from_micros(200),
        };
        let mut cfg = WsfmConfig::default();
        cfg.pipeline_depth = depth;
        cfg.draft_workers = workers;
        let ns = run_serve_bench(exec, cfg, 32);
        println!("{label:<38} {:>10.0} ns/bundle", ns);
        results.push((label.to_string(), ns));
    }
}

// ---------------------------------------------------------------------------
// Cascade: single-segment vs gated ladder (mock executor)
// ---------------------------------------------------------------------------

/// Serve the same bundle load with the cascade off (one uninterrupted
/// segment) vs gated (default [0.75, 0.9] ladder). The stage-cost mock
/// charges refine time proportional to executed NFE and fills tokens
/// with a constant — a maximally self-consistent state the proxy scores
/// high — so the gate passes after stage 1 and the gated rows show the
/// early-exit saving (≈ the skipped segments' share of refine_cost).
fn bench_cascade_throughput(results: &mut Vec<(String, f64)>) {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    for (label, mode) in [
        ("serve bundle cascade single-segment", "off"),
        ("serve bundle cascade gated", "gated"),
    ] {
        let exec = StageCostExec {
            batch,
            seq_len,
            vocab,
            draft_cost: Duration::from_micros(50),
            refine_cost: Duration::from_micros(200),
        };
        let mut cfg = WsfmConfig::default();
        cfg.pipeline_depth = 2;
        cfg.draft_workers = 1;
        cfg.cascade.mode = mode.into();
        let ns = run_serve_bench(exec, cfg, 32);
        println!("{label:<38} {:>10.0} ns/bundle", ns);
        results.push((label.to_string(), ns));
    }
}

// ---------------------------------------------------------------------------
// Composer: per-bundle refinement vs continuous cross-bundle batching
// ---------------------------------------------------------------------------

/// Executor pricing each *forward pass* at a flat `call_cost` (the fixed
/// kernel-launch/engine overhead batching amortises), then producing the
/// analytic drift probs per token. Unlike [`StageCostExec`] it leaves
/// `run_loop` at the trait default, so the per-bundle path and the
/// composed path pay the same per-step price — the only variable is how
/// many rows share each call.
struct StepCostExec {
    batch: usize,
    seq_len: usize,
    vocab: usize,
    call_cost: Duration,
}

impl Executor for StepCostExec {
    fn step_into(
        &self,
        _a: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        std::thread::sleep(self.call_cost);
        let coef = (h * warp / (1.0 - t).max(1e-6)).min(1.0);
        out.clear();
        out.reserve(tokens.len() * self.vocab);
        let base = coef / self.vocab as f32;
        for &tok in tokens {
            for j in 0..self.vocab {
                let stay = if j as i32 == tok { 1.0 - coef } else { 0.0 };
                out.push(stay + base);
            }
        }
        Ok(())
    }

    fn draft(&self, _a: &str, _noise: &[f32]) -> anyhow::Result<Vec<i32>> {
        Ok(vec![0; self.batch * self.seq_len])
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        StageCostExec {
            batch: self.batch,
            seq_len: self.seq_len,
            vocab: self.vocab,
            draft_cost: Duration::ZERO,
            refine_cost: Duration::ZERO,
        }
        .meta(artifact)
    }
}

/// Mixed concurrent load (one full bundle per request, depth-8 pipeline,
/// one REFINE stream) refined per-bundle vs through the step-level batch
/// composer. Per-bundle, every in-flight bundle pays `call_cost` per
/// Euler step on its own; composed, bundles admitted at the same step
/// boundary march in lockstep and rows on equal `(t, h, warp)` share one
/// forward pass — the call count (and wall-clock) drops toward one per
/// *composed* step. Outputs are bitwise-identical either way (pinned in
/// `coordinator::service` tests); this row prices the grouping win.
fn bench_composer_throughput(results: &mut Vec<(String, f64)>) {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    for (label, composed) in
        [("serve bundle per-bundle", false), ("serve bundle composed", true)]
    {
        let exec = StepCostExec { batch, seq_len, vocab, call_cost: Duration::from_micros(100) };
        let mut cfg = WsfmConfig::default();
        cfg.pipeline_depth = 8;
        cfg.draft_workers = 2;
        cfg.composer.enabled = composed;
        let ns = run_serve_bench(exec, cfg, 32);
        println!("{label:<38} {:>10.0} ns/bundle", ns);
        results.push((label.to_string(), ns));
    }
}

// ---------------------------------------------------------------------------
// Observability overhead on the serve path
// ---------------------------------------------------------------------------

/// Serve the same bundle load with tracing disabled vs enabled (the
/// default). The obs layer's per-bundle cost is a handful of atomic ring
/// pushes (admit/wait/draft/segment spans) behind one `enabled` load, so
/// the on/off gap bounds the telemetry tax on the hot path — the ISSUE's
/// acceptance bar is "within a few percent".
fn bench_obs_overhead(results: &mut Vec<(String, f64)>) {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    for (label, enabled) in [("serve bundle obs off", false), ("serve bundle obs on", true)] {
        let exec = StageCostExec {
            batch,
            seq_len,
            vocab,
            draft_cost: Duration::from_micros(50),
            refine_cost: Duration::from_micros(200),
        };
        let mut cfg = WsfmConfig::default();
        cfg.pipeline_depth = 2;
        cfg.draft_workers = 1;
        cfg.obs.enabled = enabled;
        let ns = run_serve_bench(exec, cfg, 32);
        println!("{label:<38} {:>10.0} ns/bundle", ns);
        results.push((label.to_string(), ns));
    }
}

/// Serve the same bundle load with the decision ledger disabled vs
/// enabled (the default), tracing held at its default in both rows so
/// the gap isolates the ledger tax: one `DecisionRecord` build + audit +
/// drift-window fold + ring push per bundle, off the token path. The
/// ISSUE's acceptance bar is the same as tracing — within a few percent.
fn bench_ledger_overhead(results: &mut Vec<(String, f64)>) {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    for (label, enabled) in [("serve bundle ledger off", false), ("serve bundle ledger on", true)]
    {
        let exec = StageCostExec {
            batch,
            seq_len,
            vocab,
            draft_cost: Duration::from_micros(50),
            refine_cost: Duration::from_micros(200),
        };
        let mut cfg = WsfmConfig::default();
        cfg.pipeline_depth = 2;
        cfg.draft_workers = 1;
        cfg.obs.ledger.enabled = enabled;
        let ns = run_serve_bench(exec, cfg, 32);
        println!("{label:<38} {:>10.0} ns/bundle", ns);
        results.push((label.to_string(), ns));
    }
}

// ---------------------------------------------------------------------------
// Watchdog overhead on the engine-call reply path
// ---------------------------------------------------------------------------

/// The robustness watchdog (`robustness.call_timeout_ms`) swaps the
/// blocking `recv()` on every engine reply for a deadline-bounded
/// `recv_timeout()`. Measure the same stats round-trip bare vs with a
/// generous, never-firing deadline armed, so the guard's overhead on the
/// fault-free hot path stays visible in the trajectory.
fn bench_watchdog_overhead(results: &mut Vec<(String, f64)>) {
    let b = Bench::default();
    let manifest = wsfm::runtime::Manifest {
        dir: std::path::PathBuf::from("/tmp"),
        artifacts: vec![],
        domains: Json::Null,
        batch_sizes: std::collections::BTreeMap::new(),
        schema_version: 1,
    };
    let bare = wsfm::runtime::EngineHandle::spawn(manifest).expect("engine thread");
    rec(results, b.run("engine call roundtrip bare", || {
        black_box(bare.stats().unwrap());
    }));
    let guarded = bare.clone().with_call_timeout(Some(Duration::from_secs(5)));
    rec(results, b.run("engine call roundtrip watchdog", || {
        black_box(guarded.stats().unwrap());
    }));
    bare.shutdown();
}

// ---------------------------------------------------------------------------
// Fleet scaling: replicated executors vs a single stream (mock executor)
// ---------------------------------------------------------------------------

/// Serve the same bundle load through a fleet of `replicas` flat-cost
/// replicas with `refine_workers = replicas`. With one replica the REFINE
/// stage is one 200 µs stream (per-bundle cost bottoms out there); with
/// four, concurrently popped bundles land on distinct replicas via the
/// least-loaded router, so per-bundle wall-clock approaches refine/4.
fn bench_fleet_throughput(results: &mut Vec<(String, f64)>) {
    let (batch, seq_len, vocab) = SERVE_BENCH_SHAPE;
    for (label, replicas) in
        [("serve bundle fleet replicas=1", 1), ("serve bundle fleet replicas=4", 4)]
    {
        let execs: Vec<Arc<dyn Executor>> = (0..replicas)
            .map(|_| {
                Arc::new(StageCostExec {
                    batch,
                    seq_len,
                    vocab,
                    draft_cost: Duration::from_micros(50),
                    refine_cost: Duration::from_micros(200),
                }) as Arc<dyn Executor>
            })
            .collect();
        let fleet = FleetHandle::from_executors(execs);
        let mut cfg = WsfmConfig::default();
        cfg.pipeline_depth = 2 * replicas;
        cfg.draft_workers = 2;
        cfg.fleet.refine_workers = replicas;
        let ns = run_serve_bench(fleet, cfg, 32);
        println!("{label:<38} {:>10.0} ns/bundle", ns);
        results.push((label.to_string(), ns));
    }
}

fn bench_engine_steps(env: &Env, results: &mut Vec<(String, f64)>) {
    let b = Bench { warmup: std::time::Duration::from_millis(300), samples: 8, ..Bench::default() };
    // One engine step per served shape: the denominator for "L3 overhead".
    let shapes: [(&str, &str, usize); 4] = [
        ("two_moons", "cold", 64),
        ("two_moons", "cold", 1024),
        ("text8", "cold", 32),
        ("img_gray", "cold", 16),
    ];
    for (domain, tag, batch) in shapes {
        let Ok(meta) = env.manifest.find_step(domain, tag, batch) else {
            eprintln!("skipping {domain}/b{batch} (not built)");
            continue;
        };
        let meta = meta.clone();
        let tokens = vec![1i32; meta.batch * meta.seq_len];
        // Warm the compile cache first.
        let _ = env.engine.step(&meta.name, &tokens, 0.5, 0.05, 1.0).unwrap();
        rec(results, b.run(&format!("engine step {domain} b{batch} (N={})", meta.seq_len), || {
            black_box(env.engine.step(&meta.name, &tokens, 0.5, 0.05, 1.0).unwrap());
        }));

        // The engine-resident loop over the same artifact: total time for a
        // short warm run, one channel round-trip.
        let params = SamplerParams {
            artifact: meta.name.clone(),
            steps_cold: 20,
            t0: 0.8,
            warp_mode: WarpMode::Literal,
        };
        let mut rng = Pcg64::new(0);
        rec(results, Bench::quick().run(&format!("engine loop {domain} b{batch} 4 steps"), || {
            let out = sample_warm(
                &env.engine,
                &params,
                TokenBatch::zeros(meta.batch, meta.seq_len),
                &mut rng,
                false,
            )
            .unwrap();
            black_box(out.nfe);
        }));
    }
}

fn bench_update_rule_ablation(env: &Env) {
    // Ablation: literal vs exact update rule, same artifact/schedule —
    // quality measured in table1; here we confirm identical cost.
    let Ok(meta) = env.manifest.find_step("two_moons", "ws_good_t080", 1024) else {
        return;
    };
    let meta = meta.clone();
    let b = Bench::quick();
    let tokens = vec![1i32; meta.batch * meta.seq_len];
    let _ = env.engine.step(&meta.name, &tokens, 0.85, 0.05, 1.0).unwrap();
    for (label, warp) in [("exact(warp=1.0)", 1.0f32), ("literal(warp=0.2)", 0.2f32)] {
        b.run(&format!("ws step {label}"), || {
            black_box(env.engine.step(&meta.name, &tokens, 0.85, 0.05, warp).unwrap());
        });
    }
}

fn write_results(results: &[(String, f64)]) {
    let pairs: Vec<(&str, Json)> =
        results.iter().map(|(name, ns)| (name.as_str(), Json::num(*ns))).collect();
    let doc = Json::obj(pairs);
    match std::fs::write("BENCH_hotpath.json", format!("{doc}\n")) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json ({} entries, mean ns/iter)", results.len()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}

fn main() {
    let mut results: Vec<(String, f64)> = Vec::new();

    println!("== L3 coordinator components ==");
    bench_l3_components(&mut results);

    println!("\n== wire codecs: json lines vs binary frames ==");
    bench_wire_codecs(&mut results);

    println!("\n== sampling-loop round-trips (mock executor, {} workers) ==", WorkerPool::shared().threads());
    bench_loop_roundtrip(&mut results);

    println!("\n== coordinator: serial vs DRAFT→REFINE pipeline ==");
    bench_pipeline_throughput(&mut results);

    println!("\n== cascade: single-segment vs gated ladder ==");
    bench_cascade_throughput(&mut results);

    println!("\n== fleet: replicated executors vs a single stream ==");
    bench_fleet_throughput(&mut results);

    println!("\n== composer: per-bundle vs continuous cross-bundle batching ==");
    bench_composer_throughput(&mut results);

    println!("\n== observability: tracing off vs on ==");
    bench_obs_overhead(&mut results);

    println!("\n== decision ledger: off vs on ==");
    bench_ledger_overhead(&mut results);

    println!("\n== watchdog: bare vs guarded engine-call reply wait ==");
    bench_watchdog_overhead(&mut results);

    match Env::load("artifacts") {
        Ok(env) => {
            println!("\n== engine steps (per served shape) ==");
            bench_engine_steps(&env, &mut results);
            println!("\n== update-rule ablation (cost) ==");
            bench_update_rule_ablation(&env);
            env.engine.shutdown();
        }
        Err(e) => eprintln!("artifacts not built; engine benches skipped: {e:#}"),
    }

    write_results(&results);
}
