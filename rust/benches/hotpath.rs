//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf) + update-rule ablation.
//!
//! Measures the L3 components around the PJRT engine call:
//! categorical sampling, batcher offer/flush, queue handoff, JSON protocol
//! encode/decode — and the engine step itself per domain/batch, so the
//! "coordinator must not be the bottleneck" target is quantified.
//!
//! `cargo bench --bench hotpath`

use std::time::Instant;
use wsfm::coordinator::batcher::{Batcher, FlushPolicy};
use wsfm::coordinator::request::{DraftSpec, GenRequest};
use wsfm::core::prob;
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::WarpMode;
use wsfm::harness::common::Env;
use wsfm::runtime::Executor;
use wsfm::util::bench::{black_box, Bench};

fn bench_l3_components() {
    let b = Bench::default();

    // 1. Categorical sampling over a [32, 64, 27] probs tensor — the only
    //    per-token L3 work per Euler step.
    let mut rng = Pcg64::new(0);
    let vocab = 27;
    let rows = 32 * 64;
    let probs: Vec<f32> = (0..rows * vocab).map(|_| rng.uniform_f32() + 0.01).collect();
    let mut out = vec![0i32; rows];
    b.run("categorical_batch 32x64x27", || {
        prob::categorical_batch(black_box(&probs), vocab, &mut out, &mut rng);
    });

    // Larger image-shaped tensor.
    let vocab2 = 32;
    let rows2 = 16 * 256;
    let probs2: Vec<f32> = (0..rows2 * vocab2).map(|_| rng.uniform_f32() + 0.01).collect();
    let mut out2 = vec![0i32; rows2];
    b.run("categorical_batch 16x256x32", || {
        prob::categorical_batch(black_box(&probs2), vocab2, &mut out2, &mut rng);
    });

    // 2. Batcher offer+flush cycle.
    let mk_req = |i: u64| GenRequest {
        id: i,
        domain: "text8".into(),
        tag: "ws_t080".into(),
        draft: DraftSpec::Lstm,
        n_samples: 1,
        t0: 0.8,
        steps_cold: 128,
        warp_mode: WarpMode::Literal,
        seed: i,
        submitted: Instant::now(),
    };
    b.run("batcher offer x32 + flush", || {
        let mut batcher =
            Batcher::new(FlushPolicy { max_batch: 32, max_wait: std::time::Duration::from_secs(1) });
        for i in 0..32 {
            if let Some(bundle) = batcher.offer(mk_req(i)) {
                black_box(bundle.total_samples());
            }
        }
        black_box(batcher.flush_all().len());
    });

    // 3. Wire protocol encode/decode.
    let line = r#"{"cmd":"generate","domain":"text8","tag":"ws_t080","draft":"lstm","n_samples":4,"t0":0.8,"steps":1024,"seed":7,"decode":true}"#;
    b.run("protocol parse_request", || {
        black_box(wsfm::server::protocol::parse_request(black_box(line)).unwrap());
    });

    // 4. RNG noise fill (draft-model input generation, 32x64x27 gumbel).
    let mut noise = vec![0.0f32; 32 * 64 * 27];
    b.run("gumbel fill 32x64x27", || {
        rng.fill_gumbel_f32(&mut noise);
        black_box(noise[0]);
    });
}

fn bench_engine_steps(env: &Env) {
    let b = Bench { warmup: std::time::Duration::from_millis(300), samples: 8, ..Bench::default() };
    // One engine step per served shape: the denominator for "L3 overhead".
    let shapes: [(&str, &str, usize); 4] = [
        ("two_moons", "cold", 64),
        ("two_moons", "cold", 1024),
        ("text8", "cold", 32),
        ("img_gray", "cold", 16),
    ];
    for (domain, tag, batch) in shapes {
        let Ok(meta) = env.manifest.find_step(domain, tag, batch) else {
            eprintln!("skipping {domain}/b{batch} (not built)");
            continue;
        };
        let meta = meta.clone();
        let tokens = vec![1i32; meta.batch * meta.seq_len];
        // Warm the compile cache first.
        let _ = env.engine.step(&meta.name, &tokens, 0.5, 0.05, 1.0).unwrap();
        b.run(&format!("engine step {domain} b{batch} (N={})", meta.seq_len), || {
            black_box(env.engine.step(&meta.name, &tokens, 0.5, 0.05, 1.0).unwrap());
        });
    }
}

fn bench_update_rule_ablation(env: &Env) {
    // Ablation: literal vs exact update rule, same artifact/schedule —
    // quality measured in table1; here we confirm identical cost.
    let Ok(meta) = env.manifest.find_step("two_moons", "ws_good_t080", 1024) else {
        return;
    };
    let meta = meta.clone();
    let b = Bench::quick();
    let tokens = vec![1i32; meta.batch * meta.seq_len];
    let _ = env.engine.step(&meta.name, &tokens, 0.85, 0.05, 1.0).unwrap();
    for (label, warp) in [("exact(warp=1.0)", 1.0f32), ("literal(warp=0.2)", 0.2f32)] {
        b.run(&format!("ws step {label}"), || {
            black_box(env.engine.step(&meta.name, &tokens, 0.85, 0.05, warp).unwrap());
        });
    }
}

fn main() {
    println!("== L3 coordinator components ==");
    bench_l3_components();

    match Env::load("artifacts") {
        Ok(env) => {
            println!("\n== engine steps (per served shape) ==");
            bench_engine_steps(&env);
            println!("\n== update-rule ablation (cost) ==");
            bench_update_rule_ablation(&env);
            env.engine.shutdown();
        }
        Err(e) => eprintln!("artifacts not built; engine benches skipped: {e:#}"),
    }
}
