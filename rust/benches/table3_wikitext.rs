//! Bench: regenerate paper Table 3 (synth-wiki perplexity/entropy/time).
//! `cargo bench --bench table3_wikitext`

use wsfm::data::corpus::load_i32_stream;
use wsfm::harness::common::Env;
use wsfm::harness::{table2, table3};

fn main() {
    let env = match Env::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping table3 bench (artifacts not built): {e:#}");
            return;
        }
    };
    let eval_stream = match load_i32_stream(&env.manifest.dir.join("wiki_eval.bin")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping (wiki corpus missing): {e:#}");
            return;
        }
    };
    let train_stream = load_i32_stream(&env.manifest.dir.join("wiki_corpus.bin")).unwrap();
    let cfg = table2::TextBenchCfg {
        domain: "wiki",
        eval_file: "wiki_eval.bin",
        eval_order: 3,
        refine_order: 3,
        vocab: 256,
        steps_cold: 128,
        n_eval: 16,
        seed: 0,
    };
    let rows =
        table2::run_text(&env, &cfg, &eval_stream, &train_stream[..train_stream.len().min(150_000)])
            .expect("table3 failed");
    table2::print("Table 3 (synth-wiki) [bench profile]", &rows, table3::PAPER, true);
    env.engine.shutdown();
}
