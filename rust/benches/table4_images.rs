//! Bench: regenerate paper Table 4 (image FID/time, gray + color).
//! `cargo bench --bench table4_images`

use wsfm::data::shapes;
use wsfm::harness::common::Env;
use wsfm::harness::table4::{self, ImageCfg};

fn main() {
    let env = match Env::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping table4 bench (artifacts not built): {e:#}");
            return;
        }
    };
    for (domain, side, channels, col) in
        [("img_gray", shapes::GRAY_SIDE, 1usize, 0usize), ("img_color", shapes::COLOR_SIDE, 3, 1)]
    {
        if env.manifest.for_domain(domain).is_empty() {
            eprintln!("skipping {domain} (not built)");
            continue;
        }
        let cfg = ImageCfg { domain: if col == 0 { "img_gray" } else { "img_color" }, side, channels, steps_cold: 48, n_eval: 48, seed: 0 };
        let rows = table4::run_images(&env, &cfg).expect("table4 failed");
        table4::print(&format!("Table 4 ({domain}) [bench profile]"), &rows, col);
    }
    env.engine.shutdown();
}
