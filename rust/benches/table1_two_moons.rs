//! Bench: regenerate paper Table 1 (two-moons SKL/NFE) + per-row timing.
//! `cargo bench --bench table1_two_moons`
//!
//! Uses the in-tree harness (criterion is not vendored — see DESIGN.md §2).

use wsfm::harness::common::Env;
use wsfm::harness::table1;

fn main() {
    let env = match Env::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping table1 bench (artifacts not built): {e:#}");
            return;
        }
    };
    let rows = table1::run(&env, 2048, 0).expect("table1 failed");
    table1::print(&rows);

    // Wall-clock scaling check: time-per-sample must scale ~ with NFE.
    println!("\nNFE scaling (s/sample ratios vs cold):");
    let cold = &rows[0];
    for r in &rows[1..] {
        println!(
            "  {:<24} nfe_ratio={:.2}  time_ratio={:.2}",
            r.label,
            cold.nfe as f64 / r.nfe as f64,
            cold.secs_per_sample / r.secs_per_sample.max(1e-12)
        );
    }
    env.engine.shutdown();
}
