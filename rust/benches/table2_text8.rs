//! Bench: regenerate paper Table 2 (synth-text8 NLL/entropy/time).
//! `cargo bench --bench table2_text8`

use wsfm::data::corpus::load_text8;
use wsfm::harness::common::Env;
use wsfm::harness::table2::{self, TextBenchCfg};

fn main() {
    let env = match Env::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping table2 bench (artifacts not built): {e:#}");
            return;
        }
    };
    let eval_stream = match load_text8(&env.manifest.dir.join("text8_eval.txt")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping (text8 corpus missing): {e:#}");
            return;
        }
    };
    let train_stream = load_text8(&env.manifest.dir.join("text8_corpus.txt")).unwrap();
    let cfg = TextBenchCfg {
        domain: "text8",
        eval_file: "text8_eval.txt",
        eval_order: 5,
        refine_order: 4,
        vocab: 27,
        steps_cold: 128, // bench-speed resolution; CLI harness defaults to 256
        n_eval: 16,
        seed: 0,
    };
    let rows =
        table2::run_text(&env, &cfg, &eval_stream, &train_stream[..train_stream.len().min(200_000)])
            .expect("table2 failed");
    table2::print("Table 2 (synth-text8) [bench profile]", &rows, table2::PAPER, false);
    env.engine.shutdown();
}
