//! Cross-language consistency: the Rust data generators must match the
//! distributions of the Python generators that trained the models
//! (DESIGN.md §2 — same constants, same grammar, independent RNGs).
//!
//! These tests compare summary statistics of the Rust generators against
//! the *materialized* Python corpora in `artifacts/` (skipped when absent).

use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::{guaranteed_nfe, Schedule};
use wsfm::data::{corpus, textgen, two_moons};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn nfe_boundary_cases_agree_with_python() {
    // `core::schedule::guaranteed_nfe` and `python/compile/paths.py::nfe`
    // share one epsilon-robust formula; these golden values are what the
    // Python side computes (regenerate with:
    //   python3 -c "import math
    //   def nfe(s,t0):
    //       eps=1e-9+s*1e-12
    //       return min(max(s,1),max(1,math.ceil(s*(1.0-t0)-eps)))" ...
    // ) and, at the grid boundaries t0 = 1 - k/steps, equal the exact
    // integer k. Before the epsilon-robust formulation, float drift in
    // `steps * (1 - t0)` could come out one high/low vs the integer
    // arithmetic for t0 near 1.
    let cases: &[(usize, f64, usize)] = &[
        // (steps_cold, t0, expected nfe)
        (20, 0.0, 20),
        (20, 0.05, 19),             // t0 = h
        (20, 0.95, 1),              // t0 = 1 - h
        (20, 0.35, 13),             // paper Table 1 boundary (13.000...02 in f64)
        (3, 1.0 - 1.0 / 3.0, 1),    // off-binary grid
        (7, 1.0 - 1.0 / 7.0, 1),
        (49, 1.0 - 1.0 / 49.0, 1),  // 49*(1/49) = 1.0000000000000009 in f64
        (1024, 0.8, 205),           // paper Table 2
        (1024, 0.5, 512),
        (1024, 0.999, 2),
        (65536, 1.0 - 13.0 / 65536.0, 13),
        (65536, 1.0 - 1e-9, 1),     // t0 hard against the upper boundary
    ];
    for &(steps, t0, want) in cases {
        assert_eq!(guaranteed_nfe(steps, t0), want, "steps={steps} t0={t0}");
        // And the schedule built from it is well-formed: positive steps,
        // lands on 1.
        let s = Schedule::new(steps, t0).unwrap();
        assert_eq!(s.nfe(), want);
        let last = s.nfe() - 1;
        assert!(s.step_size(last) > 0.0, "steps={steps} t0={t0}");
        assert!((s.times[last] + s.step_size(last) - 1.0).abs() < 1e-9);
    }
    // Dense boundary sweep: every (steps, k) grid point recovers k.
    for steps in [2usize, 5, 20, 100, 1024] {
        for k in 1..=steps.min(64) {
            let t0 = 1.0 - k as f64 / steps as f64;
            assert_eq!(guaranteed_nfe(steps, t0), k, "steps={steps} k={k}");
        }
    }
}

#[test]
fn text8_char_frequencies_match_python_corpus() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let py = match corpus::load_text8(&dir.join("text8_corpus.txt")) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("skipping: text8 corpus not built");
            return;
        }
    };
    let rust_corpus = textgen::corpus(120_000, 99);
    let rs = wsfm::data::tokenizer::CharTokenizer.encode(&rust_corpus).unwrap();

    let freq = |toks: &[i32]| -> Vec<f64> {
        let mut c = vec![0f64; 27];
        for &t in toks {
            c[t as usize] += 1.0;
        }
        let n = toks.len() as f64;
        c.iter().map(|x| x / n).collect()
    };
    let fp = freq(&py[..py.len().min(200_000)]);
    let fr = freq(&rs);
    // Total variation distance between char distributions must be tiny —
    // the two generators implement the same grammar.
    let tv: f64 = fp.iter().zip(&fr).map(|(a, b)| (a - b).abs()).sum::<f64>() / 2.0;
    assert!(tv < 0.02, "char TV distance {tv}");
}

#[test]
fn two_moons_histogram_matches_mirrored_generator() {
    // Rust-vs-Rust seeds differ but distribution identical; and if the
    // python-trained artifacts exist, the trained cold model's samples are
    // checked against the rust target generator in integration.rs. Here:
    // pin the quantization function against golden values (shared with
    // python's quantize_moons).
    assert_eq!(two_moons::quantize(0.0, 0.0), [45, 48]);
    assert_eq!(two_moons::quantize(1.0, 0.5), [82, 80]);
    assert_eq!(two_moons::quantize(-1.0, 1.0), [9, 112]);
    // And the full sampler stays distributionally stable across seeds.
    let mut a_rng = Pcg64::new(1);
    let mut b_rng = Pcg64::new(2);
    let a = two_moons::sample_batch(6000, &mut a_rng);
    let b = two_moons::sample_batch(6000, &mut b_rng);
    let d = wsfm::eval::skl::skl_points(&a, &b);
    assert!(d < 0.25, "self-SKL {d}");
}

#[test]
fn wiki_vocab_loads_and_covers_corpus() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(vocab_text) = std::fs::read_to_string(dir.join("wiki_vocab.json")) else {
        eprintln!("skipping: wiki not built");
        return;
    };
    let tok = wsfm::data::tokenizer::WordTokenizer::from_json(&vocab_text).unwrap();
    assert_eq!(tok.vocab_size(), 256);
    let stream = corpus::load_i32_stream(&dir.join("wiki_corpus.bin")).unwrap();
    assert!(stream.iter().all(|&t| (0..256).contains(&t)));
    // Round-trip a window through decode/encode.
    let window = &stream[..64];
    let text = tok.decode(window);
    let back = tok.encode(&text);
    assert_eq!(back, window);
}

#[test]
fn image_train_set_matches_shape_constants() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(gray) = corpus::load_u8_matrix(&dir.join("img_gray_train.bin"), 256) else {
        eprintln!("skipping: img_gray not built");
        return;
    };
    assert!(!gray.is_empty());
    for img in gray.iter().take(50) {
        assert!(img.iter().all(|&t| (0..32).contains(&t)));
    }
    // Python-rendered and Rust-rendered images live in the same value
    // range with similar global statistics.
    let mut rng = Pcg64::new(0);
    let (rust_imgs, _) = wsfm::data::shapes::batch_gray(200, &mut rng);
    let mean = |set: &[Vec<i32>]| -> f64 {
        set.iter().flat_map(|v| v.iter()).map(|&t| t as f64).sum::<f64>()
            / (set.len() * set[0].len()) as f64
    };
    let mp = mean(&gray[..200.min(gray.len())]);
    let mr = mean(&rust_imgs);
    assert!((mp - mr).abs() < 4.0, "mean tokens: python {mp} vs rust {mr}");
}
