//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! Every test loads `artifacts/manifest.json`; if it is absent the tests
//! skip (so `cargo test` stays green on a fresh checkout before the
//! artifact build). The Makefile's `test` target builds artifacts first, so
//! CI always exercises the real path.

use std::time::Instant;
use wsfm::config::WsfmConfig;
use wsfm::coordinator::request::{DraftSpec, GenRequest};
use wsfm::coordinator::{Scheduler, Service};
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::{guaranteed_nfe, WarpMode};
use wsfm::metrics::ServingMetrics;
use wsfm::runtime::{EngineHandle, Executor, Manifest};
use wsfm::server::{Client, TcpServer};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn request(domain: &str, tag: &str, draft: DraftSpec, n: usize, t0: f64, steps: usize) -> GenRequest {
    GenRequest {
        id: 0,
        domain: domain.into(),
        tag: tag.into(),
        draft,
        n_samples: n,
        t0,
        steps_cold: steps,
        warp_mode: WarpMode::Literal,
        seed: 7,
        timing: false,
        submitted: Instant::now(),
    }
}

/// The checked-in schema-v2 fixture (no `make artifacts` needed): loads,
/// carries a content hash, and verifies bit-for-bit — the same check the
/// CI reproducible-manifest step runs via `wsfm verify-artifacts`.
#[test]
fn checked_in_fixture_manifest_verifies() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/manifest_v2");
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.schema_version, 2);
    assert!(m.artifacts[0].content_hash.is_some());
    let report = m.verify_hashes().unwrap();
    assert!(report.ok(), "{report}");
    assert_eq!((report.verified, report.unhashed), (1, 0));
}

/// The checked-in decision-ledger fixture (no `make artifacts` needed):
/// every line parses as a `DecisionRecord`, the file is not torn, and
/// every record passes the guarantee auditor — the same invariants the
/// CI wire-compat job exercises via `wsfm audit` / `wsfm replay` on this
/// file. Guards the fixture against ledger schema drift.
#[test]
fn checked_in_fixture_ledger_parses_and_audits_clean() {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/ledger_v1.jsonl");
    let (records, torn) = wsfm::obs::ledger::read_ledger(&path).unwrap();
    assert!(!torn, "fixture ledger must end on a complete line");
    assert_eq!(records.len(), 3);
    for rec in &records {
        assert_eq!(wsfm::obs::ledger::audit(rec), Ok(()), "bundle {}", rec.bundle_id);
    }
    // One refined, one early-exit cascade, one degraded record — the
    // three decision shapes the auditor distinguishes.
    assert!(!records[0].degraded && !records[0].early_exit);
    assert!(records[1].early_exit && records[1].exit_score.is_some());
    assert!(records[2].degraded && records[2].nfe == 0);
    // Round trip: canonical JSON survives parse → render → parse.
    for rec in &records {
        let back = wsfm::obs::ledger::DecisionRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(&back, rec);
    }
}

#[test]
fn manifest_selfcheck_passes() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    manifest.selfcheck().unwrap();
    assert!(manifest.domain_names().contains(&"two_moons".to_string()));
}

#[test]
fn engine_executes_step_artifact_with_valid_probs() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let meta = manifest.find_step("two_moons", "cold", 64).unwrap().clone();
    let engine = EngineHandle::spawn(manifest).unwrap();
    let tokens = vec![5i32; meta.batch * meta.seq_len];
    let probs = engine.step(&meta.name, &tokens, 0.5, 0.05, 1.0).unwrap();
    assert_eq!(probs.len(), meta.batch * meta.seq_len * meta.vocab);
    // Rows are distributions.
    for row in probs.chunks(meta.vocab) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "row sum {s}");
        assert!(row.iter().all(|&p| p >= 0.0));
    }
    engine.shutdown();
}

#[test]
fn engine_rejects_wrong_shapes() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let meta = manifest.find_step("two_moons", "cold", 1).unwrap().clone();
    let engine = EngineHandle::spawn(manifest).unwrap();
    assert!(engine.step(&meta.name, &[1, 2, 3], 0.5, 0.05, 1.0).is_err());
    engine.shutdown();
}

#[test]
fn nfe_guarantee_holds_on_real_artifacts() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = EngineHandle::spawn(manifest.clone()).unwrap();
    let metrics = ServingMetrics::default();
    let sched = Scheduler::new(&engine, &manifest, &metrics, 0);
    for (t0, tag) in [(0.8, "ws_good_t080"), (0.5, "ws_fair_t050")] {
        let draft = if tag.contains("good") {
            DraftSpec::Mixture(wsfm::data::two_moons::DraftKind::Good)
        } else {
            DraftSpec::Mixture(wsfm::data::two_moons::DraftKind::Fair)
        };
        let resp = sched.run_single(request("two_moons", tag, draft, 1, t0, 20)).unwrap();
        assert_eq!(resp.nfe, guaranteed_nfe(20, t0), "t0={t0}");
        assert_eq!(resp.samples.len(), 1);
    }
    assert_eq!(metrics.denoiser_calls.get(), (guaranteed_nfe(20, 0.8) + guaranteed_nfe(20, 0.5)) as u64);
    engine.shutdown();
}

#[test]
fn deterministic_generation_per_seed() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = EngineHandle::spawn(manifest.clone()).unwrap();
    let metrics = ServingMetrics::default();
    let sched = Scheduler::new(&engine, &manifest, &metrics, 0);
    let run = |seed: u64| {
        let mut req = request("two_moons", "cold", DraftSpec::Noise, 4, 0.0, 10);
        req.seed = seed;
        sched.run_single(req).unwrap().samples
    };
    assert_eq!(run(1), run(1));
    assert_ne!(run(1), run(2));
    engine.shutdown();
}

#[test]
fn warm_samples_stay_closer_to_target_than_noise() {
    // Sanity on the science: WS good-draft output should score much better
    // SKL than uniform noise does.
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = EngineHandle::spawn(manifest.clone()).unwrap();
    let metrics = ServingMetrics::default();
    let sched = Scheduler::new(&engine, &manifest, &metrics, 0);
    let mut rng = Pcg64::new(3);
    let resp = sched
        .run_single(request(
            "two_moons",
            "ws_good_t080",
            DraftSpec::Mixture(wsfm::data::two_moons::DraftKind::Good),
            512,
            0.8,
            20,
        ))
        .unwrap();
    let pts: Vec<[i32; 2]> = resp.samples.iter().map(|s| [s[0], s[1]]).collect();
    let target = wsfm::data::two_moons::sample_batch(2048, &mut rng);
    let noise: Vec<[i32; 2]> =
        (0..512).map(|_| [rng.below(128) as i32, rng.below(128) as i32]).collect();
    let skl_ws = wsfm::eval::skl::skl_points(&target, &pts);
    let skl_noise = wsfm::eval::skl::skl_points(&target, &noise);
    assert!(skl_ws < skl_noise * 0.5, "ws {skl_ws} vs noise {skl_noise}");
    engine.shutdown();
}

#[test]
fn lstm_draft_artifact_generates_plausible_text() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    if manifest.find_draft("text8", "lstm", 8).is_err() {
        eprintln!("skipping: text8 artifacts not built");
        return;
    }
    let engine = EngineHandle::spawn(manifest.clone()).unwrap();
    let metrics = ServingMetrics::default();
    let sched = Scheduler::new(&engine, &manifest, &metrics, 0);
    let resp = sched
        .run_single(request("text8", "ws_t080", DraftSpec::Lstm, 4, 0.8, 64))
        .unwrap();
    let tok = wsfm::data::tokenizer::CharTokenizer;
    for s in &resp.samples {
        let text = tok.decode(s);
        assert_eq!(text.len(), 64);
        // A trained draft+refine pipeline produces spaces (words), unlike
        // uniform noise which is ~96% letters.
        assert!(text.contains(' '), "no spaces in {text:?}");
    }
    engine.shutdown();
}

#[test]
fn tcp_server_end_to_end() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = EngineHandle::spawn(manifest.clone()).unwrap();
    let mut cfg = WsfmConfig::default();
    cfg.artifacts_dir = dir.clone();
    cfg.batcher.max_wait_us = 1000;
    let service = Service::start(engine.clone(), manifest.clone(), cfg);
    let server = TcpServer::bind("127.0.0.1:0", service.clone(), manifest).unwrap();
    let addr = server.local_addr.to_string();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());

    let mut client = Client::connect(&addr).unwrap();
    assert!(client.ping().unwrap());
    let reply = client.generate("two_moons", "cold", "noise", 3, 0.0, 10, 1, false).unwrap();
    assert_eq!(reply.samples.len(), 3);
    assert_eq!(reply.nfe, 10);
    let m = client.metrics().unwrap();
    assert!(m.get("completed").as_f64().unwrap_or(0.0) >= 1.0);
    client.shutdown().unwrap();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = handle.join().unwrap();
    service.shutdown();
    engine.shutdown();
}

#[test]
fn concurrent_clients_share_batches() {
    let dir = require_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = EngineHandle::spawn(manifest.clone()).unwrap();
    let mut cfg = WsfmConfig::default();
    cfg.batcher.max_batch = 8;
    cfg.batcher.max_wait_us = 20_000;
    let service = Service::start(engine.clone(), manifest.clone(), cfg);

    let mut rxs = Vec::new();
    for i in 0..8 {
        let mut r = request("two_moons", "cold", DraftSpec::Noise, 1, 0.0, 10);
        r.seed = i;
        rxs.push(service.submit(r).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap().unwrap();
        assert_eq!(resp.samples.len(), 1);
    }
    // 8 single-sample requests at max_batch 8 ride a small number of
    // batcher bundles. The executor-chunk count depends on the planner's
    // padding/dispatch trade-off (two_moons compiles {1, 64, 1024}, and
    // padding 8 rows to 64 exceeds the 4x cap, so chunks stay b1): assert
    // the bundle-level sharing instead — all requests complete with zero
    // padded rows and no more chunks than requests.
    let batches = service.metrics.batches_executed.get();
    assert!(batches <= 8, "batches = {batches}");
    assert_eq!(service.metrics.padded_rows.get(), 0);
    service.shutdown();
    engine.shutdown();
}
