//! Engine-resident sampling loop: public-API contract tests.
//!
//! These run without artifacts (a pure-Rust drift executor stands in for
//! the PJRT engine) and pin the three guarantees of the refactor:
//!
//! 1. **Seed parity** — `sample_warm` (engine-resident `run_loop` path)
//!    and `sample_warm_stepwise` (legacy per-step path) produce identical
//!    tokens for the same seed.
//! 2. **Zero steady-state allocation** — scratch capacity stops growing
//!    after the first step and stays fixed across runs.
//! 3. **Deterministic parallelism** — the row-parallel categorical
//!    sampler is bitwise-equal to its sequential reference for any worker
//!    count.

use std::sync::atomic::{AtomicUsize, Ordering};
use wsfm::core::prob;
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::WarpMode;
use wsfm::core::tensor::TokenBatch;
use wsfm::core::workers::WorkerPool;
use wsfm::runtime::{ArtifactMeta, Executor, LoopScratch, LoopSpec, TensorSpec};
use wsfm::sampler::{sample_warm, sample_warm_stepwise, SamplerParams};

/// A denoiser that drifts every position toward `target_token` with rate
/// proportional to h/(1-t), plus a little mass everywhere so sampling
/// stays stochastic.
struct DriftExec {
    batch: usize,
    seq_len: usize,
    vocab: usize,
    target_token: usize,
    step_calls: AtomicUsize,
}

impl DriftExec {
    fn new(batch: usize, seq_len: usize, vocab: usize, target_token: usize) -> Self {
        DriftExec { batch, seq_len, vocab, target_token, step_calls: AtomicUsize::new(0) }
    }
}

impl Executor for DriftExec {
    fn step_into(
        &self,
        _artifact: &str,
        tokens: &[i32],
        t: f32,
        h: f32,
        warp: f32,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.step_calls.fetch_add(1, Ordering::SeqCst);
        let coef = (h * warp / (1.0 - t).max(1e-6)).min(1.0);
        out.clear();
        out.reserve(tokens.len() * self.vocab);
        for &tok in tokens {
            for j in 0..self.vocab {
                let stay = if j as i32 == tok { 1.0 - coef } else { 0.0 };
                let pull = if j == self.target_token { 0.8 * coef } else { 0.0 };
                out.push(stay + pull + 0.2 * coef / self.vocab as f32);
            }
        }
        Ok(())
    }

    fn draft(&self, _artifact: &str, _noise: &[f32]) -> anyhow::Result<Vec<i32>> {
        anyhow::bail!("no drafts here")
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<ArtifactMeta> {
        Ok(ArtifactMeta {
            name: artifact.to_string(),
            hlo_file: String::new(),
            domain: "mock".into(),
            kind: "step".into(),
            tag: "cold".into(),
            draft: None,
            batch: self.batch,
            seq_len: self.seq_len,
            vocab: self.vocab,
            t0: Some(0.0),
            latent_dim: None,
            inputs: vec![TensorSpec {
                name: "x_t".into(),
                shape: vec![self.batch, self.seq_len],
                dtype: "s32".into(),
            }],
            outputs: vec![TensorSpec {
                name: "probs".into(),
                shape: vec![self.batch, self.seq_len, self.vocab],
                dtype: "f32".into(),
            }],
            content_hash: None,
        })
    }
}

fn params(t0: f64, steps: usize) -> SamplerParams {
    SamplerParams {
        artifact: "drift".into(),
        steps_cold: steps,
        t0,
        warp_mode: WarpMode::Exact,
    }
}

#[test]
fn engine_resident_and_stepwise_paths_are_seed_identical() {
    for (t0, steps) in [(0.0, 16), (0.5, 32), (0.8, 20)] {
        let exec = DriftExec::new(8, 32, 5, 3);
        let init = TokenBatch::zeros(8, 32);
        let mut rng = Pcg64::new(1234);
        let a = sample_warm(&exec, &params(t0, steps), init, &mut rng, false).unwrap();

        let exec2 = DriftExec::new(8, 32, 5, 3);
        let init2 = TokenBatch::zeros(8, 32);
        let mut rng2 = Pcg64::new(1234);
        let b = sample_warm_stepwise(&exec2, &params(t0, steps), init2, &mut rng2, false).unwrap();

        assert_eq!(a.tokens, b.tokens, "t0={t0} steps={steps}");
        assert_eq!(a.nfe, b.nfe);
        assert_eq!(
            exec.step_calls.load(Ordering::SeqCst),
            exec2.step_calls.load(Ordering::SeqCst),
            "both paths must evaluate the denoiser exactly nfe times"
        );
    }
}

#[test]
fn run_loop_performs_exactly_nfe_denoiser_calls() {
    let exec = DriftExec::new(4, 8, 4, 1);
    let init = TokenBatch::zeros(4, 8);
    let mut rng = Pcg64::new(0);
    let out = sample_warm(&exec, &params(0.8, 20), init, &mut rng, false).unwrap();
    assert_eq!(out.nfe, 4); // ceil(20 * 0.2)
    assert_eq!(exec.step_calls.load(Ordering::SeqCst), 4);
    // And the drift actually happened: target token dominates.
    let hits = out.tokens.tokens.iter().filter(|&&t| t == 1).count();
    assert!(hits > out.tokens.tokens.len() / 2, "{hits}");
}

#[test]
fn scratch_capacity_is_flat_in_steady_state() {
    let exec = DriftExec::new(4, 16, 6, 2);
    let mut scratch = LoopScratch::default();
    let spec =
        |steps: usize, seed: u64| LoopSpec::full("drift".into(), steps, 0.0, 1.0, seed, false);
    let mut tokens = vec![0i32; 4 * 16];
    let token_cap = tokens.capacity();

    exec.run_loop(&spec(1, 7), &mut tokens, &mut scratch).unwrap();
    let cap = scratch.probs.capacity();
    assert!(cap >= 4 * 16 * 6, "scratch must reach B*N*V once: {cap}");

    for (steps, seed) in [(100usize, 8u64), (3, 9), (250, 10)] {
        exec.run_loop(&spec(steps, seed), &mut tokens, &mut scratch).unwrap();
        assert_eq!(scratch.probs.capacity(), cap, "no per-step or per-run growth");
        assert_eq!(tokens.capacity(), token_cap, "tokens resampled in place");
    }
}

#[test]
fn parallel_categorical_is_bitwise_stable_across_pool_sizes() {
    let (rows, vocab) = (2048, 16);
    let mut rng = Pcg64::new(5);
    let probs: Vec<f32> = (0..rows * vocab).map(|_| rng.uniform_f32() + 1e-3).collect();
    let mut reference = vec![0i32; rows];
    prob::categorical_batch_seeded(&probs, vocab, &mut reference, 77, 4);
    for threads in [1usize, 2, 5, 16] {
        let pool = WorkerPool::new(threads);
        let mut out = vec![0i32; rows];
        prob::categorical_batch_par(&probs, vocab, &mut out, 77, 4, &pool);
        assert_eq!(out, reference, "threads={threads}");
    }
}

#[test]
fn trace_is_identical_between_paths() {
    let exec = DriftExec::new(2, 4, 3, 2);
    let init = TokenBatch::zeros(2, 4);
    let mut rng = Pcg64::new(21);
    let a = sample_warm(&exec, &params(0.5, 8), init, &mut rng, true).unwrap();
    let exec2 = DriftExec::new(2, 4, 3, 2);
    let init2 = TokenBatch::zeros(2, 4);
    let mut rng2 = Pcg64::new(21);
    let b = sample_warm_stepwise(&exec2, &params(0.5, 8), init2, &mut rng2, true).unwrap();
    let (ta, tb) = (a.trace.unwrap(), b.trace.unwrap());
    assert_eq!(ta.times, tb.times);
    assert_eq!(ta.states, tb.states);
    assert_eq!(ta.len(), a.nfe + 1);
}
