//! Two-moons end-to-end walkthrough: reproduce the paper's §4.1 experiment
//! programmatically — drafts of three qualities, warm-start refinement at
//! each paper t0, quality-vs-NFE frontier printed as a small report.
//!
//! ```bash
//! cargo run --release --example two_moons_e2e
//! ```

use anyhow::Result;
use wsfm::coordinator::request::{DraftSpec, GenRequest};
use wsfm::coordinator::Scheduler;
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::WarpMode;
use wsfm::data::two_moons::{self, DraftKind};
use wsfm::eval::skl::skl_points;
use wsfm::metrics::ServingMetrics;
use wsfm::runtime::{EngineHandle, Manifest};

fn main() -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = EngineHandle::spawn(manifest.clone())?;
    let metrics = ServingMetrics::default();
    let scheduler = Scheduler::new(&engine, &manifest, &metrics, 0);
    let mut rng = Pcg64::new(0);
    let n = 1024;
    let target = two_moons::sample_batch(4096, &mut rng);

    // Draft quality before any refinement (paper Fig. 4 c-e).
    println!("draft quality (SKL vs target, no refinement):");
    for kind in [DraftKind::Good, DraftKind::Fair, DraftKind::Poor] {
        let drafts = two_moons::draft_batch(kind, n, &mut rng);
        println!("  {:<5} SKL = {:.3}", kind.name(), skl_points(&target, &drafts));
    }

    // Cold baseline.
    let run = |tag: &str, draft, t0| -> Result<(f64, usize)> {
        let resp = scheduler.run_single(GenRequest {
            id: 0,
            domain: "two_moons".into(),
            tag: tag.into(),
            draft,
            n_samples: n,
            t0,
            steps_cold: 20,
            warp_mode: WarpMode::Literal,
            seed: 1,
            submitted: std::time::Instant::now(),
        })?;
        let pts: Vec<[i32; 2]> = resp.samples.iter().map(|s| [s[0], s[1]]).collect();
        Ok((skl_points(&target, &pts), resp.nfe))
    };

    let (cold_skl, cold_nfe) = run("cold", DraftSpec::Noise, 0.0)?;
    println!("\ncold DFM: SKL = {cold_skl:.3} at NFE = {cold_nfe}");

    println!("\nwarm-start frontier (paper Table 1 grid):");
    println!("{:<8}{:>6}{:>8}{:>8}  verdict", "draft", "t0", "NFE", "SKL");
    for (kind, t0s) in [
        (DraftKind::Good, vec![0.95f64, 0.9, 0.8]),
        (DraftKind::Fair, vec![0.8, 0.5]),
        (DraftKind::Poor, vec![0.8, 0.5, 0.35]),
    ] {
        for t0 in t0s {
            let tag = format!("ws_{}_t{:03}", kind.name(), (t0 * 100.0).round() as u32);
            let (skl, nfe) = run(&tag, DraftSpec::Mixture(kind), t0)?;
            let verdict = if skl <= cold_skl * 1.05 {
                format!("no worse than cold at {}x speed-up", cold_nfe / nfe)
            } else {
                "quality degraded (t0 too aggressive for this draft)".to_string()
            };
            println!("{:<8}{:>6}{:>8}{:>8.3}  {}", kind.name(), t0, nfe, skl, verdict);
        }
    }

    println!(
        "\nconclusion: better drafts tolerate larger t0 — the paper's core\ntrade-off — and NFE is always exactly ceil(20*(1-t0))."
    );
    engine.shutdown();
    Ok(())
}
