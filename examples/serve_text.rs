//! End-to-end serving driver (the repo's headline e2e example): starts the
//! full stack (engine thread → coordinator → TCP server), drives it with a
//! multi-threaded client load generator issuing WS-DFM text requests, and
//! reports latency percentiles + throughput. Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example serve_text -- [n_clients] [reqs_per_client] [steps]
//! ```

use anyhow::Result;
use std::sync::atomic::Ordering;
use std::time::Instant;
use wsfm::config::WsfmConfig;
use wsfm::coordinator::Service;
use wsfm::runtime::{EngineHandle, Manifest};
use wsfm::server::{Client, TcpServer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_clients: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let reqs_per_client: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(128);

    // Boot the full stack.
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = EngineHandle::spawn(manifest.clone())?;
    let mut cfg = WsfmConfig::default();
    cfg.batcher.max_batch = 8; // text8 largest compiled batch is 32
    cfg.batcher.max_wait_us = 5_000;
    let service = Service::start(engine.clone(), manifest.clone(), cfg);
    let server = TcpServer::bind("127.0.0.1:0", service.clone(), manifest)?;
    let addr = server.local_addr.to_string();
    let stop = server.stop_handle();
    let server_thread = std::thread::spawn(move || server.run());
    println!("serving on {addr}; warming up the text8 WS pipeline...");

    // Warm-up: compile the artifacts before measuring.
    {
        let mut c = Client::connect(&addr)?;
        c.generate("text8", "ws_t080", "lstm", 1, 0.8, steps, 0, false)?;
    }

    // Load generation: n_clients threads, each issuing sequential requests.
    let t_start = Instant::now();
    let mut handles = Vec::new();
    for client_id in 0..n_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<(u64, u64, usize)>> {
            let mut client = Client::connect(&addr)?;
            let mut stats = Vec::new();
            for i in 0..reqs_per_client {
                let t = Instant::now();
                let reply = client.generate(
                    "text8",
                    "ws_t080",
                    "lstm",
                    2,
                    0.8,
                    steps,
                    (client_id * 1000 + i) as u64,
                    true,
                )?;
                stats.push((t.elapsed().as_micros() as u64, reply.queue_us, reply.nfe));
            }
            Ok(stats)
        }));
    }

    let mut latencies = Vec::new();
    let mut queue_waits = Vec::new();
    let mut nfes = Vec::new();
    for h in handles {
        for (lat, qw, nfe) in h.join().unwrap()? {
            latencies.push(lat);
            queue_waits.push(qw);
            nfes.push(nfe);
        }
    }
    let wall = t_start.elapsed();

    latencies.sort_unstable();
    let pct = |p: f64| latencies[((p / 100.0) * (latencies.len() - 1) as f64).round() as usize];
    let total_reqs = latencies.len();
    let total_samples = total_reqs * 2;
    println!("\n=== e2e serving results (text8, WS-DFM t0=0.8, {steps} cold steps) ===");
    println!("clients={n_clients} requests={total_reqs} samples={total_samples}");
    println!("NFE per request: {} (guaranteed ceil({steps}*0.2))", nfes[0]);
    println!(
        "request latency: p50={:.1}ms p95={:.1}ms max={:.1}ms",
        pct(50.0) as f64 / 1e3,
        pct(95.0) as f64 / 1e3,
        *latencies.last().unwrap() as f64 / 1e3
    );
    println!(
        "mean queue wait: {:.1}ms",
        queue_waits.iter().sum::<u64>() as f64 / queue_waits.len() as f64 / 1e3
    );
    println!(
        "throughput: {:.2} req/s, {:.2} samples/s (wall {:.2}s)",
        total_reqs as f64 / wall.as_secs_f64(),
        total_samples as f64 / wall.as_secs_f64(),
        wall.as_secs_f64()
    );
    println!("\nserver metrics:\n{}", service.metrics.report());

    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join().unwrap();
    service.shutdown();
    engine.shutdown();
    Ok(())
}
