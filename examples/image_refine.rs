//! Image refinement demo (paper §4.3 / Fig. 7): generate PCA drafts, refine
//! them with WS-DFM at t0 = 0.5, and write a progress strip of PGM images
//! showing the draft → refined trajectory, plus FID before/after.
//!
//! ```bash
//! cargo run --release --example image_refine -- [out_dir]
//! ```

use anyhow::{Context, Result};
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::WarpMode;
use wsfm::data::corpus::load_u8_matrix;
use wsfm::data::shapes;
use wsfm::draft::{Draft, DraftNoise, HloDraft};
use wsfm::eval::fid::{fid_images, FeatureExtractor};
use wsfm::runtime::{EngineHandle, Executor, Manifest};
use wsfm::sampler::dfm::{sample_warm, SamplerParams};

fn main() -> Result<()> {
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "out/image_refine".into());
    let out_dir = std::path::Path::new(&out_dir);
    std::fs::create_dir_all(out_dir)?;

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = EngineHandle::spawn(manifest.clone())?;
    let mut rng = Pcg64::new(9);
    let steps_cold = 64;
    let t0 = 0.5;

    // Phase DRAFT: PCA-Gaussian samples (the DC-GAN substitute).
    let b = 16;
    let step_meta = manifest.find_step("img_gray", "ws_t050", b)?.clone();
    let draft_meta = manifest.find_draft("img_gray", "pca", b)?.clone();
    let draft = HloDraft::new(&engine as &dyn Executor, draft_meta.name, DraftNoise::Gaussian);
    let init = draft.generate(b, step_meta.seq_len, &mut rng)?;

    // Phase REFINE with a full trace for the progress strip.
    let params = SamplerParams {
        artifact: step_meta.name.clone(),
        steps_cold,
        t0,
        warp_mode: WarpMode::Literal,
    };
    let drafts_copy = init.clone();
    let out = sample_warm(&engine, &params, init, &mut rng, true)?;
    println!(
        "refined {} images in {} NFE ({:?}) — cold would take {}",
        b, out.nfe, out.elapsed, steps_cold
    );

    // Write progress strips for the first 4 images (paper Fig. 7 layout).
    let trace = out.trace.context("trace missing")?;
    for row in 0..4 {
        for (j, (t, tokens)) in trace.row_snapshots(row, 6).iter().enumerate() {
            let name = format!("strip_row{row}_s{j}_t{:.2}.pgm", t);
            shapes::write_pgm(&out_dir.join(name), tokens, shapes::GRAY_SIDE)?;
        }
    }

    // FID before vs after refinement, against the training distribution.
    let train = load_u8_matrix(
        &manifest.dir.join("img_gray_train.bin"),
        shapes::GRAY_SIDE * shapes::GRAY_SIDE,
    )?;
    let reference: Vec<Vec<i32>> = train.into_iter().take(1024).collect();
    let extractor = FeatureExtractor::new(shapes::GRAY_SIDE, 1, 8, 0xF1D);
    let draft_rows: Vec<Vec<i32>> = (0..b).map(|i| drafts_copy.row(i).to_vec()).collect();
    let refined_rows: Vec<Vec<i32>> = (0..b).map(|i| out.tokens.row(i).to_vec()).collect();
    let fid_draft = fid_images(&extractor, &reference, &draft_rows);
    let fid_refined = fid_images(&extractor, &reference, &refined_rows);
    println!("FID*: draft = {fid_draft:.2}  ->  refined = {fid_refined:.2} (lower is better)");
    println!("progress strips written to {out_dir:?}");
    engine.shutdown();
    Ok(())
}
