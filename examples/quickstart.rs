//! Quickstart: load the artifacts, generate two-moons samples cold and
//! warm, and show the guaranteed speed-up.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use wsfm::coordinator::request::{DraftSpec, GenRequest};
use wsfm::coordinator::Scheduler;
use wsfm::core::rng::Pcg64;
use wsfm::core::schedule::{speedup_factor, WarpMode};
use wsfm::data::two_moons::DraftKind;
use wsfm::metrics::ServingMetrics;
use wsfm::runtime::{EngineHandle, Manifest};

fn main() -> Result<()> {
    // 1. Load the AOT artifact index and start the PJRT engine thread.
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = EngineHandle::spawn(manifest.clone())?;
    let metrics = ServingMetrics::default();
    let scheduler = Scheduler::new(&engine, &manifest, &metrics, 0);
    let mut rng = Pcg64::new(42);

    let request = |tag: &str, draft, t0| GenRequest {
        id: 0,
        domain: "two_moons".into(),
        tag: tag.into(),
        draft,
        n_samples: 256,
        t0,
        steps_cold: 20,
        warp_mode: WarpMode::Literal,
        seed: 42,
        submitted: std::time::Instant::now(),
    };

    // 2. Cold DFM: 20 Euler steps from uniform noise (paper Fig. 3 left).
    let cold = scheduler.run_single(request("cold", DraftSpec::Noise, 0.0))?;
    println!(
        "cold DFM   : {} samples, NFE = {:>2}, refine = {:?}",
        cold.samples.len(),
        cold.nfe,
        cold.refine_time
    );

    // 3. WS-DFM: start at t0 = 0.8 from the "pretty good" draft model —
    //    guaranteed 5x fewer denoiser calls (paper §3).
    let warm =
        scheduler.run_single(request("ws_good_t080", DraftSpec::Mixture(DraftKind::Good), 0.8))?;
    println!(
        "WS-DFM 0.8 : {} samples, NFE = {:>2}, refine = {:?}  (guaranteed {}x speed-up)",
        warm.samples.len(),
        warm.nfe,
        warm.refine_time,
        speedup_factor(0.8)
    );

    // 4. Quality check: symmetric KL against fresh target samples.
    let target = wsfm::data::two_moons::sample_batch(4096, &mut rng);
    let to_pts = |samples: &[Vec<i32>]| -> Vec<[i32; 2]> {
        samples.iter().map(|s| [s[0], s[1]]).collect()
    };
    let skl_cold = wsfm::eval::skl::skl_points(&target, &to_pts(&cold.samples));
    let skl_warm = wsfm::eval::skl::skl_points(&target, &to_pts(&warm.samples));
    println!("SKL cold = {skl_cold:.3}, SKL warm = {skl_warm:.3} (lower is better)");
    println!(
        "warm used {}x fewer denoiser calls at {} quality",
        cold.nfe / warm.nfe,
        if skl_warm <= skl_cold * 1.05 { "no worse" } else { "reduced" }
    );
    engine.shutdown();
    Ok(())
}
